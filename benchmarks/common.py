"""Shared benchmark plumbing: CSV emission + the paper's default setup."""

from __future__ import annotations

import csv
import os
import sys
import time

from repro.chip.config import TB, ChipConfig, ipu_pod4_hbm

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")
DESIGNS = ("Basic", "Static", "ELK-Dyn", "ELK-Full", "Ideal")
PAPER_MODELS = ("llama2_13b", "gemma2_27b", "opt_30b", "llama2_70b")

_out_dir = OUT_DIR


def out_dir() -> str:
    """Directory every benchmark section writes its JSON/CSV under
    (``benchmarks.run --out-dir`` overrides the default)."""
    return _out_dir


def set_out_dir(path: str) -> None:
    global _out_dir
    _out_dir = path
    os.makedirs(path, exist_ok=True)


def emit(name: str, rows: list[dict]) -> str:
    os.makedirs(_out_dir, exist_ok=True)
    path = os.path.join(_out_dir, f"{name}.csv")
    if rows:
        fields: list[str] = []
        for r in rows:
            for k in r:
                if k not in fields:
                    fields.append(k)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields, restval="")
            w.writeheader()
            w.writerows(rows)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {os.path.normpath(path)}")
    return path


def default_chip(**kw) -> ChipConfig:
    return ipu_pod4_hbm(**kw)
