"""One function per paper table/figure (Figures 12, 16-24).

Every function returns CSV rows and writes experiments/bench/<name>.csv.
The emulated-hardware timing comes from the ELK plans + the event
simulator (`chip/simulator.py`), matching the paper's emulator/simulator
split (DESIGN.md §2).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import DESIGNS, PAPER_MODELS, default_chip, emit
from repro.chip.config import TB, ipu_pod4_hbm
from repro.chip.simulator import simulate
from repro.configs import get_config
from repro.core.baselines import build_plan
from repro.core.cost_model import (AnalyticCostModel, fit_link_cost_model,
                                   fit_tile_cost_model)
from repro.core.elk import compare_designs, compile_model
from repro.core.graph import build_graph


def fig_fusion() -> list[dict]:
    """Fusion-on vs fusion-off round time per §6.1 design on the
    compute-intensive prefill configs (DESIGN.md §8): where the fused MLP
    chain pays off, and that the base-vs-fused selection never regresses
    a design that gains nothing from it."""
    import dataclasses

    chip = default_chip()
    rows = []
    for model, seq in (("dit_xl", 256), ("opt_30b", 512)):
        cfg = dataclasses.replace(get_config(model), num_layers=8)
        off = compare_designs(cfg, chip, batch=1, seq=seq, phase="prefill",
                              designs=("Static", "ELK-Full"), cache=False)
        on = compare_designs(cfg, chip, batch=1, seq=seq, phase="prefill",
                             designs=("Static", "ELK-Full"), fusion=True,
                             cache=False)
        for d in off:
            rows.append({
                "model": model, "design": d,
                "latency_off_ms": round(off[d].total_time * 1e3, 4),
                "latency_on_ms": round(on[d].total_time * 1e3, 4),
                "fused_graph_won": on[d].fusion,
                "gain_pct": round(
                    (1 - on[d].total_time / off[d].total_time) * 100, 3),
            })
    emit("fig_fusion", rows)
    return rows


def fig12_costmodel() -> list[dict]:
    """Cost-model accuracy: linear-tree regressor vs the analytic ground
    truth (the paper fits against profiled IPU tiles; no IPU exists here,
    so agreement is tree-vs-analytic — DESIGN.md §4)."""
    chip = default_chip()
    rows = []
    for kind in ("matmul", "vector"):
        tree, X, y = fit_tile_cost_model(chip, kind, n_samples=512)
        pred = tree.predict(X)
        err = np.abs(pred - y) / np.maximum(np.abs(y), 1e-12)
        rows.append({"target": f"tile_{kind}",
                     "median_rel_err": round(float(np.median(err)), 4),
                     "p90_rel_err": round(float(np.quantile(err, .9)), 4)})
    tree, X, y = fit_link_cost_model(chip)
    pred = tree.predict(X)
    err = np.abs(pred - y) / np.maximum(np.abs(y), 1e-12)
    rows.append({"target": "link_transfer",
                 "median_rel_err": round(float(np.median(err)), 4),
                 "p90_rel_err": round(float(np.quantile(err, .9)), 4)})
    emit("fig12_costmodel", rows)
    return rows


def fig16_compile_time() -> list[dict]:
    rows = []
    chip = default_chip()
    for model in PAPER_MODELS:
        cfg = get_config(model)
        t0 = time.perf_counter()
        plan = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                             design="ELK-Full", max_orders=8)
        dt = time.perf_counter() - t0
        rows.append({"model": model, "compile_s": round(dt, 2),
                     "ops": len(plan.graph.ops),
                     "extrapolated_from": plan.extrapolated_from_layers})
    emit("fig16_compile_time", rows)
    return rows


def fig17_latency(batches=(16, 32), seqs=(2048,)) -> list[dict]:
    rows = []
    chip = default_chip()
    for model in PAPER_MODELS:
        cfg = get_config(model)
        for b in batches:
            for s in seqs:
                plans = compare_designs(cfg, chip, batch=b, seq=s,
                                        phase="decode")
                ideal = plans["Ideal"].total_time
                for d, p in plans.items():
                    rows.append({
                        "model": model, "batch": b, "seq": s, "design": d,
                        "latency_ms": round(p.total_time * 1e3, 3),
                        "vs_ideal": round(ideal / p.total_time, 4)})
    emit("fig17_latency", rows)
    return rows


def fig18_breakdown(model="llama2_13b", batch=32, seq=2048) -> list[dict]:
    rows = []
    chip = default_chip()
    plans = compare_designs(get_config(model), chip, batch=batch, seq=seq,
                            phase="decode")
    for d, p in plans.items():
        bd = p.breakdown
        rows.append({
            "design": d,
            "preload_only_ms": round(bd.preload_only * 1e3, 3),
            "execute_only_ms": round(bd.execute_only * 1e3, 3),
            "overlapped_ms": round(bd.overlapped * 1e3, 3),
            "interconnect_ms": round(bd.interconnect_stall * 1e3, 3),
            "hbm_util": round(p.util.hbm, 4),
            "noc_util": round(p.util.interconnect, 4),
            "tflops": round(p.util.achieved_tflops, 1),
        })
    emit("fig18_breakdown", rows)
    return rows


def fig19_20_hbm_sweep(model="llama2_13b", batch=32, seq=2048) -> list[dict]:
    rows = []
    for bw_tb in (4, 8, 16, 32):
        chip = ipu_pod4_hbm(hbm_bw=bw_tb * TB)
        plans = compare_designs(get_config(model), chip, batch=batch,
                                seq=seq, phase="decode")
        for d, p in plans.items():
            rows.append({"model": model, "hbm_tb": bw_tb, "design": d,
                         "latency_ms": round(p.total_time * 1e3, 3),
                         "hbm_util": round(p.util.hbm, 4),
                         "stall_ms": round(
                             p.breakdown.interconnect_stall * 1e3, 3)})
    emit("fig19_hbm_sweep", rows)
    return rows


def fig21_topology(model="llama2_13b", batch=32, seq=2048) -> list[dict]:
    rows = []
    for topo in ("all2all", "mesh2d"):
        for bw_tb in (8, 16):
            chip = ipu_pod4_hbm(hbm_bw=bw_tb * TB, topology=topo)
            plans = compare_designs(get_config(model), chip, batch=batch,
                                    seq=seq, phase="decode",
                                    designs=("Basic", "ELK-Full", "Ideal"))
            for d, p in plans.items():
                rows.append({"topology": topo, "hbm_tb": bw_tb, "design": d,
                             "latency_ms": round(p.total_time * 1e3, 3),
                             "noc_util": round(p.util.interconnect, 4)})
    emit("fig21_topology", rows)
    return rows


def fig22_noc_sweep(model="llama2_70b", batch=32, seq=2048) -> list[dict]:
    rows = []
    base = default_chip()
    for link_scale in (0.5, 1.0, 2.0):
        for bw_tb in (8, 16):
            chip = base.scaled(link_bw=base.link_bw * link_scale,
                               hbm_bw=bw_tb * TB)
            plans = compare_designs(get_config(model), chip, batch=batch,
                                    seq=seq, phase="decode",
                                    designs=("Basic", "ELK-Full", "Ideal"))
            for d, p in plans.items():
                rows.append({"noc_scale": link_scale, "hbm_tb": bw_tb,
                             "design": d,
                             "latency_ms": round(p.total_time * 1e3, 3)})
    emit("fig22_noc_sweep", rows)
    return rows


def fig23_cores(model="dit_xl", batch=32, seq=256) -> list[dict]:
    """Core-count scaling (incl. the DiT-XL compute-bound case)."""
    rows = []
    base = default_chip()
    for cores in (1472, 2944, 5888):
        chip = base.scaled(
            num_cores=cores,
            hbm_bw=2.7e9 * cores,              # paper: 2.7GB/s per core
            core_flops=base.core_flops,
        )
        plans = compare_designs(get_config(model), chip, batch=batch,
                                seq=seq, phase="decode",
                                designs=("Basic", "Static", "ELK-Full",
                                         "Ideal"))
        for d, p in plans.items():
            rows.append({"model": model, "cores": cores, "design": d,
                         "latency_ms": round(p.total_time * 1e3, 3)})
    emit("fig23_cores", rows)
    return rows


def fig24_topology(model="llama2_13b", batch=32, seq=2048,
                   topologies=("all2all", "mesh2d", "torus2d", "ring",
                               "hier_pod")) -> list[dict]:
    """§6.4 topology DSE: the interconnect topology is a first-class axis
    of the simulator toolkit.  Per-topology plan latency for Basic /
    ELK-Full / Ideal plus an event-simulated latency on a 2-layer
    truncation (per-link-class contention), reproducing the sensitivity
    story across >= 4 topologies."""
    from repro.chip.dse import topology_sweep
    rows = topology_sweep(get_config(model), topologies, batch=batch,
                          seq=seq, designs=("Basic", "ELK-Full", "Ideal"),
                          max_orders=24)
    emit("fig24_topology", rows)
    return rows


def fig24_training(model="llama2_13b", batch=8, seq=2048) -> list[dict]:
    """Training forward pass TFLOPS vs compute/bandwidth scaling."""
    rows = []
    base = default_chip()
    for flops_scale in (0.5, 1.0, 2.0):
        for bw_tb in (0.4, 4, 16):
            chip = base.scaled(core_flops=base.core_flops * flops_scale,
                               core_flops_vector=base.core_flops_vector
                               * flops_scale, hbm_bw=bw_tb * TB)
            plan = compile_model(get_config(model), chip, batch=batch,
                                 seq=seq, phase="train_fwd",
                                 design="ELK-Full", max_orders=4)
            rows.append({"flops_scale": flops_scale, "hbm_tb": bw_tb,
                         "tflops": round(plan.util.achieved_tflops, 1),
                         "latency_ms": round(plan.total_time * 1e3, 2)})
    emit("fig24_training", rows)
    return rows


def simulator_validation(model="llama2_13b", batch=32, seq=2048
                         ) -> list[dict]:
    """Event simulator vs scheduler estimate (the emulator-validates-
    simulator step of §5)."""
    import dataclasses
    rows = []
    chip = default_chip()
    cfg = dataclasses.replace(get_config(model), num_layers=2)
    for design in ("Basic", "ELK-Dyn"):
        g = build_graph(cfg, batch=batch, seq=seq, phase="decode")
        plan = build_plan(g, chip, design)
        sim = simulate(plan, chip)
        rows.append({"design": design,
                     "plan_ms": round(plan.total_time * 1e3, 3),
                     "sim_ms": round(sim.total_time * 1e3, 3),
                     "ratio": round(sim.total_time / plan.total_time, 3)})
    emit("simulator_validation", rows)
    return rows
