"""§Roofline report: aggregate the dry-run JSONs into the per-(arch x
shape x mesh) three-term table (compute / memory / collective seconds,
dominant term, MODEL_FLOPS/HLO ratio, roofline fraction).

Reads experiments/dryrun/*.json produced by ``repro.launch.dryrun``; the
accounting records (``__acct``) carry the scan-corrected terms and are
preferred, falling back to the production record.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records() -> dict:
    recs = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        key = (r["arch"], r["shape"], r["mesh"],
               "acct" if r.get("kind") == "accounting" else "prod")
        recs[key] = r
    return recs


def roofline_table() -> list[dict]:
    recs = load_records()
    rows = []
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in archs:
        for shape in shapes:
            prod = recs.get((arch, shape, "single", "prod"))
            acct = recs.get((arch, shape, "single", "acct"))
            if prod is None:
                continue
            if prod.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape,
                             "status": "skipped",
                             "note": prod["reason"][:60]})
                continue
            row = {"arch": arch, "shape": shape, "status": prod["status"]}
            if prod.get("status") == "ok":
                mem = prod.get("memory", {})
                row["hbm_gib_per_dev"] = round(
                    mem.get("total_hbm_bytes", 0) / 2 ** 30, 2)
                row["fits_16gb"] = prod.get("fits_16gb")
                row["compile_s"] = prod.get("compile_s")
            src = None
            if acct and acct.get("status") == "ok":
                src = acct.get("roofline_flash") or acct["roofline"]
                row["terms_from"] = "accounting"
            elif prod.get("status") == "ok":
                src = prod["roofline"]
                row["terms_from"] = "production(scan-undercounted)"
            if src:
                row.update(
                    compute_s=round(src["compute_s"], 4),
                    memory_s=round(src["memory_s"], 4),
                    collective_s=round(src["collective_s"], 4),
                    dominant=src["dominant"],
                    bound_ms=round(src["bound_s"] * 1e3, 2),
                    useful_flops=round(src["useful_flops_ratio"], 3),
                    roofline_frac=round(src["roofline_fraction"], 4),
                )
            rows.append(row)
    emit("roofline_table", rows)
    return rows


def multi_pod_table() -> list[dict]:
    """Multi-pod compile proof: every cell's 2x16x16 record."""
    recs = load_records()
    rows = []
    for (arch, shape, mesh, kind), r in sorted(recs.items()):
        if mesh != "multi" or kind != "prod":
            continue
        row = {"arch": arch, "shape": shape, "status": r["status"]}
        if r["status"] == "ok":
            row["hbm_gib_per_dev"] = round(
                r["memory"].get("total_hbm_bytes", 0) / 2 ** 30, 2)
            row["compile_s"] = r.get("compile_s")
            row["collectives"] = "+".join(
                f"{k}:{v}" for k, v in sorted(
                    r["collectives"]["counts"].items()))
        elif r["status"] == "skipped":
            row["note"] = r["reason"][:50]
        rows.append(row)
    emit("multipod_table", rows)
    return rows


if __name__ == "__main__":
    roofline_table()
    multi_pod_table()
