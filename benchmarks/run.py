"""Benchmark driver: one section per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--quick]``."""

from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    t0 = time.time()
    from benchmarks import paper_figs, roofline, validate_paper

    sections = [
        ("fig12_costmodel", paper_figs.fig12_costmodel),
        ("fig16_compile_time", paper_figs.fig16_compile_time),
        ("fig17_latency", paper_figs.fig17_latency),
        ("fig18_breakdown", paper_figs.fig18_breakdown),
        ("fig19_hbm_sweep", paper_figs.fig19_20_hbm_sweep),
        ("fig21_topology", paper_figs.fig21_topology),
        ("fig22_noc_sweep", paper_figs.fig22_noc_sweep),
        ("fig23_cores", paper_figs.fig23_cores),
        ("fig24_training", paper_figs.fig24_training),
        ("simulator_validation", paper_figs.simulator_validation),
        ("validate_paper", validate_paper.validate),
        ("roofline_table", roofline.roofline_table),
        ("multipod_table", roofline.multi_pod_table),
    ]
    if quick:
        keep = {"fig12_costmodel", "fig18_breakdown", "validate_paper",
                "roofline_table"}
        sections = [s for s in sections if s[0] in keep]

    for name, fn in sections:
        print(f"\n===== {name} =====")
        t = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"[ERROR] {name}: {type(e).__name__}: {e}")
        print(f"----- {name} done in {time.time() - t:.1f}s")
    print(f"\nall benchmarks finished in {time.time() - t0:.1f}s; "
          f"CSVs in experiments/bench/")


if __name__ == "__main__":
    main()
