"""Benchmark driver: one section per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--quick] [--section NAME ...]
[--out-dir DIR]``.

Every section writes its JSON/CSV under one output directory
(``experiments/bench/`` by default); the ``BENCH_*.json`` files are
additionally copied to the repo root for the trajectory tooling.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time

from benchmarks import common


def _coverage(obj) -> int:
    """Recursive dict-key count — a cheap 'how much does this JSON cover'
    measure used to catch a --quick run clobbering a full run's root copy
    (fewer modes/models => strictly fewer keys)."""
    if isinstance(obj, dict):
        return len(obj) + sum(_coverage(v) for v in obj.values())
    if isinstance(obj, list):
        return sum(_coverage(v) for v in obj)
    return 0


def _write_json(name: str, payload: dict) -> str:
    """Write a section's JSON under the out-dir + keep a root copy for the
    trajectory tooling; returns the primary path."""
    path = os.path.join(common.out_dir(), name)
    os.makedirs(common.out_dir(), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    root_copy = os.path.join(os.path.dirname(__file__), "..", name)
    if os.path.exists(root_copy):
        try:
            with open(root_copy) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = None
        if old is not None and _coverage(payload) < _coverage(old):
            print(f"WARNING: {name}: overwriting a fuller root copy "
                  f"({_coverage(old)} keys) with a partial run "
                  f"({_coverage(payload)} keys) — rerun without --quick "
                  f"to restore full coverage")
    shutil.copyfile(path, root_copy)
    print(f"wrote {os.path.normpath(path)} "
          f"(root copy {os.path.normpath(root_copy)})")
    return path


def bench_compile(quick: bool = False) -> None:
    """Per-design compile wall-clock + plan quality -> BENCH_compile.json.

    Tracks the pass-pipeline refactor's speedup in the bench trajectory:
    cold compile (plan cache cleared), cached recompile, and the plan's
    ``total_time`` for every §6.1 design on the paper's decode shape.
    """
    from repro.chip.config import ipu_pod4_hbm
    from repro.configs import get_config
    from repro.core.elk import compile_model
    from repro.core.pipeline import clear_plan_cache

    chip = ipu_pod4_hbm()
    models = ("opt_30b",) if quick else ("opt_30b", "llama2_13b")
    out: dict = {"chip": chip.name, "batch": 32, "seq": 2048,
                 "phase": "decode", "models": {}}
    for model in models:
        cfg = get_config(model)
        rec = {}
        for design in ("Basic", "Static", "ELK-Dyn", "ELK-Full"):
            clear_plan_cache()
            t0 = time.perf_counter()
            plan = compile_model(cfg, chip, batch=32, seq=2048,
                                 phase="decode", design=design)
            cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                          design=design)
            warm = time.perf_counter() - t0
            rec[design] = {"compile_s": round(cold, 4),
                           "cached_compile_s": round(warm, 6),
                           "plan_total_time": plan.total_time}
            print(f"  {model:12s} {design:9s} compile={cold:7.2f}s "
                  f"cached={warm*1e3:7.3f}ms plan={plan.total_time:.6g}s")
        out["models"][model] = rec
    _write_json("BENCH_compile.json", out)


def bench_serve(quick: bool = False) -> None:
    """Static vs continuous batching on a mixed-length request trace ->
    BENCH_serve.json (tok/s + p50/p99 request latency).

    Smoke-scale on purpose (CPU CI): what's measured is the *scheduling*
    delta — the lock-step batch pays padded prefill and the batch-max step
    count while continuous batching refills finished slots — not kernel
    speed.  The continuous path's per-request outputs are bit-identical to
    unpadded lock-step ``generate`` (tests/test_serve_batcher.py); the
    static baseline is a cost model of padded lock-step serving, so the
    comparison is equal scheduled work, not equal token streams.
    """
    import jax

    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import transformer as tfm
    from repro.serve.batcher import (ContinuousBatcher, make_trace,
                                     run_static_trace, summarize)
    from repro.serve.engine import ServeConfig, ServeEngine

    n = 12 if quick else 24
    cfg = get_smoke_config("qwen3_14b")
    mesh = make_local_mesh()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    out: dict = {"arch": "qwen3_14b (smoke)", "requests": n, "modes": {}}
    for mode in ("gspmd",) if quick else ("gspmd", "elk_stream"):
        eng = ServeEngine(cfg, mesh, params, ServeConfig(
            batch=4, cache_capacity=64, mode=mode, prefill_chunk=16))
        trace = make_trace(n, vocab_size=cfg.vocab_size)
        warm = make_trace(4, vocab_size=cfg.vocab_size, seed=1)
        ContinuousBatcher(eng).run(warm)
        run_static_trace(eng, warm)

        t0 = time.perf_counter()
        cont = ContinuousBatcher(eng).run(trace)
        cont_stats = summarize(cont, time.perf_counter() - t0)
        t0 = time.perf_counter()
        static = run_static_trace(eng, trace)
        static_stats = summarize(static, time.perf_counter() - t0)
        out["modes"][mode] = {"continuous": cont_stats,
                              "static": static_stats}
        speedup = (cont_stats["gen_tok_s"]
                   / max(static_stats["gen_tok_s"], 1e-9))
        out["modes"][mode]["continuous_speedup"] = round(speedup, 3)
        print(f"  {mode:10s} static={static_stats['gen_tok_s']:8.1f} tok/s "
              f"p99={static_stats['p99_latency_s']:.3f}s | "
              f"continuous={cont_stats['gen_tok_s']:8.1f} tok/s "
              f"p99={cont_stats['p99_latency_s']:.3f}s "
              f"({speedup:.2f}x)")

    out["kv_offload"] = _bench_kv_offload(cfg, mesh, params, quick)
    _write_json("BENCH_serve.json", out)


def _bench_kv_offload(cfg, mesh, params, quick: bool) -> dict:
    """KV-cache tier offload + prefix reuse on a finite-backing-tier pod
    (DESIGN.md §11), with the CI ``kvoffload-smoke`` gates:

    (a) the oversubscribed batcher sustains >= the capacity-capped
        baseline's gen tok/s on a bursty trace with 2x the physical slot
        concurrency (the strict win is pinned deterministically in
        ``tests/test_kv_offload.py`` via scheduler tick counts — wall
        clock on shared CI only gates the ordering);
    (b) prefix-cached greedy output is bit-identical to the cold
        ``generate`` path;
    (c) the event-simulated spill traffic prices within 2x of the
        planner's ``slot_spill_s`` total (one shared cost vocabulary).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.chip.config import GB, ipu_mk2
    from repro.chip.dse import kv_offload_sweep
    from repro.chip.simulator import simulate_kv_traffic
    from repro.serve.batcher import ContinuousBatcher, make_trace, summarize
    from repro.serve.engine import ServeEngine, elk_serve_config
    from repro.serve.prefix import PrefixStore

    from repro.models import transformer as tfm

    # a deeper smoke model than the serve bench's: per-tick decode compute
    # must dominate the fixed per-dispatch overhead a refill pays, or the
    # slots the capped scheduler idles during prefill cost nothing on CPU
    cfg = dataclasses.replace(cfg, num_layers=max(cfg.num_layers, 8))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    chip = ipu_mk2().with_stacked_dram(2 * GB)
    scfg = elk_serve_config(cfg, batch=2, cache_capacity=64, num_chips=1,
                            pod=chip)
    # measure the scheduler, not elk_stream's gather compile time (CPU CI)
    scfg = dataclasses.replace(scfg, mode="gspmd", prefill_chunk=8)
    eng = ServeEngine(cfg, mesh, params, scfg)
    kv: dict = {"chip": "ipu_mk2 + 2GB stacked (all-finite)",
                "oversub_k": round(scfg.oversub, 3),
                "slot_spill_us": round(scfg.slot_spill_s * 1e6, 3),
                "prefix_cache_mb": scfg.prefix_cache_bytes >> 20}

    # the gate compares wall-clock throughput, so even --quick keeps the
    # full trace: fewer requests shrink the measured win below CI noise
    n = 16
    # bursty arrivals at 2x the physical slot count, 3/4 sharing a
    # two-chunk system prompt — the traffic prefix reuse feeds on (every
    # prompt + its decode budget stays inside the 64-token ring)
    trace = make_trace(n, vocab_size=cfg.vocab_size,
                       prompt_lens=(18, 24, 30, 32), max_new=(6, 10, 14, 8),
                       burst=2 * scfg.slots, sys_prompt_len=16,
                       sys_prompt_frac=0.75, seed=7)
    # warm every code path both runs will take — chunk jits, the slot step,
    # and the extract/offload/refill jits — so neither timed run pays a
    # compile
    warm = make_trace(6, vocab_size=cfg.vocab_size,
                      prompt_lens=(18, 24, 30, 40), seed=8)
    ContinuousBatcher(eng, oversub=1.0).run(warm)
    ContinuousBatcher(eng, oversub=scfg.oversub,
                      prefix_store=PrefixStore(8 << 20)).run(warm)

    def run_once(make_batcher):
        bat = make_batcher()
        t0 = time.perf_counter()
        return bat, summarize(bat.run(trace), time.perf_counter() - t0)

    def make_capped():
        return ContinuousBatcher(eng, oversub=1.0)

    def make_over():
        # swap_after sized to the decode lengths: LRU swaps are the
        # fairness lever, refill-ahead is the throughput one — a
        # tick-scale timeslice would thrash rings mid-decode on requests
        # this short
        return ContinuousBatcher(eng, swap_after=16,
                                 prefix_store=PrefixStore(
                                     max(scfg.prefix_cache_bytes, 8 << 20)))

    # interleaved best-of-3: a load spike on shared CI hits both arms of
    # the comparison instead of deciding the throughput gate
    capped = over = None
    for _ in range(3):
        c, o = run_once(make_capped), run_once(make_over)
        if capped is None or c[1]["gen_tok_s"] > capped[1]["gen_tok_s"]:
            capped = c
        if over is None or o[1]["gen_tok_s"] > over[1]["gen_tok_s"]:
            over = o
    capped, capped_stats = capped
    over, over_stats = over
    kv["capped"] = capped_stats
    kv["oversubscribed"] = over_stats
    kv["spill_events"] = len(over.spill_events)
    kv["prefix_hits"] = over.prefix_hits
    kv["prefix_tokens_saved"] = over.prefix_tokens_saved
    print(f"  kv_offload K={scfg.oversub:.1f}: "
          f"capped={capped_stats['gen_tok_s']:.1f} tok/s | "
          f"oversub={over_stats['gen_tok_s']:.1f} tok/s "
          f"(p50 ttft {capped_stats['p50_ttft_s']:.3f}s -> "
          f"{over_stats['p50_ttft_s']:.3f}s, "
          f"{over.prefix_hits} prefix hits, "
          f"{len(over.spill_events)} spills)")

    if scfg.oversub <= 1.0:
        raise RuntimeError("finite-tier config did not produce K>1")
    if over_stats["gen_tok_s"] < capped_stats["gen_tok_s"]:
        raise RuntimeError(
            f"oversubscribed throughput {over_stats['gen_tok_s']} tok/s "
            f"fell below the capacity-capped baseline "
            f"{capped_stats['gen_tok_s']} tok/s")

    # (b) prefix-hit bit-identity against the cold generate path
    store = PrefixStore(8 << 20)
    by_rid = {r.rid: r for r in trace}
    probe = [r for r in trace if len(r.prompt) > 8][:3]
    ContinuousBatcher(eng, prefix_store=store).run(
        [dataclasses.replace(probe[0])])          # warm the store
    if store.hits + len(store) == 0:
        raise RuntimeError("prefix store took no snapshots")
    outs = ContinuousBatcher(eng, prefix_store=store).run(probe)
    if store.hits == 0:
        raise RuntimeError("prefix store saw no hits on repeated prompts")
    for c in outs:
        r = by_rid[c.rid]
        ref = np.asarray(eng.generate(
            jnp.tile(jnp.asarray(r.prompt)[None, :], (scfg.batch, 1)),
            steps=r.max_new_tokens))[0]
        if not np.array_equal(c.tokens, ref):
            raise RuntimeError(
                f"prefix-cached output diverged from cold generate for "
                f"request {c.rid}")
    kv["prefix_bit_identical"] = True

    # (c) sim-vs-planner spill pricing within 2x
    if over.spill_events:
        sim = simulate_kv_traffic(chip, over.spill_events)
        ratio = sim.total_time / max(over.planned_spill_s, 1e-12)
        kv["planned_spill_s"] = round(over.planned_spill_s, 6)
        kv["sim_spill_s"] = round(sim.total_time, 6)
        kv["spill_plan_sim_ratio"] = round(ratio, 3)
        if not 0.5 <= ratio <= 2.0:
            raise RuntimeError(
                f"simulated spill traffic deviates >2x from the planner: "
                f"ratio={ratio:.3f}")

    kv["sweep"] = kv_offload_sweep(smoke=False, sizes_gb=(16, 64),
                                   slots=4) if quick else \
        kv_offload_sweep(smoke=False)
    return kv


def bench_pipeline(quick: bool = False) -> None:
    """Stage-count x chip-count sweep of the pipeline-parallel pod planner
    (DESIGN.md §7) -> fig_pipeline.csv.

    Fails the section when the event-simulated steady-state interval
    deviates more than 2x from the planner's estimate — the CI
    ``pipeline-smoke`` job runs this with ``--fast``.
    """
    import dataclasses

    from repro.chip.dse import pipeline_sweep
    from repro.configs import get_config

    models = ("opt_30b", "qwen3_14b")
    rows = []
    for model in models:
        cfg = get_config(model)
        if quick:
            # truncate so every stage plan is exact and the planner's
            # interval is simulated end-to-end (CI smoke scale)
            cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 8))
        rows += pipeline_sweep(cfg, num_chips_list=(1, 2, 4),
                               sim_layers=8 if quick else 12)
    from benchmarks.common import emit
    emit("fig_pipeline", rows)
    bad = [r for r in rows
           if r["plan_sim_ratio"] != "" and not
           0.5 <= r["plan_sim_ratio"] <= 2.0]
    if bad:
        raise RuntimeError(
            "simulated steady-state interval deviates >2x from the "
            "planner's estimate: " + "; ".join(
                f"{r['model']} chips={r['num_chips']} stages={r['stages']} "
                f"ratio={r['plan_sim_ratio']}" for r in bad))
    multi = [r for r in rows if r["num_chips"] == 4 and r["stages"] == 4]
    for r in multi:
        print(f"  {r['model']:10s} 4-chip pipeline {r['batch_interval_ms']}"
              f" ms/decode-round vs replicated {r['replicated_ms']} ms "
              f"({r['speedup_vs_replicated']}x)")


def bench_fusion(quick: bool = False) -> None:
    """Inter-core fusion pass (DESIGN.md §8) on the compute-intensive
    prefill configs -> BENCH_fusion.json.

    For dit_xl and opt_30b prefill, compiles ELK-Full with the fusion
    knob off and on against one shared context and reports planner +
    event-simulator round times.  Fails the section when fusion-on is
    slower than fusion-off anywhere (the selection contract), when no
    config improves, or when the simulator deviates more than 2x from the
    planner on a fusion-on plan — the CI ``fusion-smoke`` job runs this.
    """
    import dataclasses

    from repro.chip.config import ipu_pod4_hbm
    from repro.chip.simulator import simulate
    from repro.configs import get_config
    from repro.core.elk import compile_model
    from repro.core.pipeline import CompileContext

    chip = ipu_pod4_hbm()
    layers = 4 if quick else 8
    configs = [("dit_xl", 256), ("opt_30b", 512)]
    out: dict = {"chip": chip.name, "phase": "prefill", "layers": layers,
                 "models": {}}
    bad, gains = [], []
    for model, seq in configs:
        cfg = dataclasses.replace(get_config(model), num_layers=layers)
        ctx = CompileContext(chip)
        kw = dict(batch=1, seq=seq, phase="prefill", ctx=ctx, cache=False)
        off = compile_model(cfg, chip, **kw)
        on = compile_model(cfg, chip, fusion=True, **kw)
        ratio = simulate(on, chip).total_time / on.total_time
        gain = 1.0 - on.total_time / off.total_time
        out["models"][model] = {
            "seq": seq,
            "plan_off_ms": round(off.total_time * 1e3, 5),
            "plan_on_ms": round(on.total_time * 1e3, 5),
            "fused_graph_won": on.fusion,
            "gain_pct": round(gain * 100, 3),
            "sim_plan_ratio": round(ratio, 3),
        }
        print(f"  {model:10s} off={off.total_time*1e3:8.4f}ms "
              f"on={on.total_time*1e3:8.4f}ms fused={on.fusion} "
              f"gain={gain*100:5.2f}% sim/plan={ratio:.2f}")
        if on.total_time > off.total_time * (1 + 1e-9):
            bad.append(f"{model}: fusion-on slower than fusion-off")
        if not 0.5 <= ratio <= 2.0:
            bad.append(f"{model}: sim/plan ratio {ratio:.2f} outside 2x")
        gains.append(gain)
    if max(gains) <= 0:
        bad.append("fusion improved no compute-intensive config")
    _write_json("BENCH_fusion.json", out)
    if bad:
        raise RuntimeError("; ".join(bad))


def bench_hybrid(quick: bool = False) -> None:
    """Joint hybrid-parallelism sweep (DESIGN.md §9) -> BENCH_hybrid.json
    + fig_hybrid.csv.

    Pure pipeline vs the joint (cut x width x replicas x microbatch) plan
    on a 4-chip pod across all five topologies, with the hybrid plan
    event-simulated (replica servers + intra-stage collectives).  Fails
    the section when hybrid's per-request time is worse than pipeline
    anywhere (the planner is never-worse by construction, so that is a
    regression) or when the simulated steady interval deviates more than
    2x from the planner's — the CI ``hybrid-smoke`` job runs this with
    ``--fast``.
    """
    from benchmarks.common import emit
    from repro.chip.dse import hybrid_sweep

    models = ("opt_30b",) if quick else ("opt_30b", "llama2_70b",
                                         "kimi_k2_1t_a32b")
    rows = hybrid_sweep(models, sim_layers=8)
    emit("fig_hybrid", rows)
    bad = []
    for r in rows:
        tag = f"{r['model']}/{r['topology']}"
        if r["hybrid_req_us"] > r["pipe_req_us"] * (1 + 1e-9):
            bad.append(f"{tag}: hybrid per-request {r['hybrid_req_us']}us "
                       f"worse than pipeline {r['pipe_req_us']}us")
        if r["plan_sim_ratio"] != "" and not \
                0.5 <= r["plan_sim_ratio"] <= 2.0:
            bad.append(f"{tag}: sim/plan ratio {r['plan_sim_ratio']} "
                       f"outside 2x")
        print(f"  {r['model']:16s} {r['topology']:8s} "
              f"pipe={r['pipe_req_us']:9.3f}us/req "
              f"hybrid={r['hybrid_req_us']:9.3f}us/req "
              f"({r['hybrid_speedup']}x) w={r['widths']} r={r['replicas']} "
              f"M={r['microbatches']} sim/plan={r['plan_sim_ratio']}")
    out = {"num_chips": 4, "batch": 32, "seq": 2048, "sim_layers": 8,
           "hybrid_wins": sum(r["hybrid_won"] for r in rows),
           "rows": rows}
    _write_json("BENCH_hybrid.json", out)
    if bad:
        raise RuntimeError("; ".join(bad))


def bench_memtier(quick: bool = False) -> None:
    """Tiered-memory DSE sweep (DESIGN.md §10) -> BENCH_memtier.json +
    fig_memtier.csv.

    Gates three contracts, CI-enforced by the ``memtier-smoke`` job:

    * two-tier bit-identity — an explicitly passed two-tier ``mem_tiers``
      spec plans exactly like the default scalar-field chip;
    * never-worse + strict improvement — no swept stacked-DRAM point may
      plan slower than the base pod, and at least one must plan strictly
      faster (the acceptance design point: 8GB @ 16TB/s);
    * simulator agreement — ``simulate_pipeline`` within 2x of the
      planner's steady interval on the base row and every improved row.
    """
    import dataclasses

    from benchmarks.common import emit
    from repro.chip.config import ipu_pod4_hbm
    from repro.chip.dse import tier_sweep
    from repro.configs import get_config
    from repro.core.elk import compile_model

    bad = []

    # -- gate 1: explicit two-tier spec is bit-identical to the default --
    chip = ipu_pod4_hbm()
    explicit = chip.scaled(mem_tiers=chip.mem_tiers)
    cfg = dataclasses.replace(get_config("opt_30b"), num_layers=2)
    kw = dict(batch=4, seq=2048, phase="decode", design="ELK-Full",
              max_orders=2, cache=False)
    a = compile_model(cfg, chip, **kw)
    b = compile_model(cfg, explicit, **kw)
    identical = (
        a.total_time == b.total_time
        and a.preload_order == b.preload_order
        and all(da.exec_plan.key() == db.exec_plan.key()
                and da.src_tier == db.src_tier
                for da, db in zip(a.decisions, b.decisions)))
    print(f"  two-tier bit-identity: {'OK' if identical else 'BROKEN'} "
          f"(plan={a.total_time * 1e3:.4f}ms)")
    if not identical:
        bad.append("explicit two-tier mem_tiers spec no longer plans "
                   "bit-identically to the default chip")

    # -- gates 2+3: the stacked-DRAM sweep ------------------------------
    sizes = (8.0,) if quick else (4.0, 8.0, 16.0)
    bws = (2.0, 16.0) if quick else (2.0, 8.0, 16.0)
    rows = tier_sweep(sizes_gb=sizes, bws_tbps=bws)
    emit("fig_memtier", rows)
    base = next(r for r in rows if r["tier"] == "none")
    swept = [r for r in rows if r["tier"] != "none"]
    improved = [r for r in swept if r["improved"]]
    for r in rows:
        tag = (f"{r['tier']:7s}" if r["tier"] == "none" else
               f"{r['tier']} {r['size_gb']:g}GB@{r['bw_tbps']:g}TB/s")
        print(f"  {tag:22s} round={r['round_ms']:.4f}ms "
              f"speedup={r['speedup']:.4f} staged={r['staged_mb']:8.1f}MB "
              f"sim/plan={r['plan_sim_ratio']:.3f}")
    for r in swept:
        if r["speedup"] < 1.0 - 1e-9:
            bad.append(f"stacked {r['size_gb']}GB@{r['bw_tbps']}TB/s plans "
                       f"slower than the base pod ({r['speedup']:.4f}x)")
    if not improved:
        bad.append("no swept stacked-DRAM point strictly improves the "
                   "planned decode round")
    for r in [base] + improved:
        if not 0.5 <= r["plan_sim_ratio"] <= 2.0:
            bad.append(f"{r['tier']} {r.get('size_gb', '')}: sim/plan "
                       f"ratio {r['plan_sim_ratio']} outside 2x")
    out = {"chip": chip.name, "model": "opt_30b", "two_tier_identical":
           identical, "improved_points": len(improved),
           "best_speedup": max((r["speedup"] for r in swept), default=1.0),
           "rows": rows}
    _write_json("BENCH_memtier.json", out)
    if bad:
        raise RuntimeError("; ".join(bad))


def bench_fleet(quick: bool = False) -> None:
    """Fleet-tier serving (DESIGN.md §12) -> BENCH_fleet.json.

    Everything runs on the fleet's virtual clock, so the numbers are
    deterministic scheduling deltas (CI-stable), not host wall time.
    Gates three contracts, CI-enforced by the ``fleet-smoke`` job:

    * degenerate equivalence — a FleetRouter over one mixed pod produces
      the same completions and the same summary as driving the
      ContinuousBatcher directly on the same virtual clock;
    * disaggregation wins — on a long-prefill/short-decode burst, a
      2-prefill + 2-decode fleet strictly beats 4 mixed replicas on
      generated tok/s AND p99 TTFT (the chunk-budget asymmetry
      ``elk_serve_config`` role sizing buys, minus the migrations it
      costs);
    * migration is charged — the router's planned migration time is
      within 2x of ``simulate_fleet_traffic`` re-serving the same event
      list on serial per-tier servers.
    """
    import jax
    import numpy as np

    from repro.chip.config import ipu_pod4_hbm
    from repro.chip.dse import fleet_sweep
    from repro.chip.simulator import simulate_fleet_traffic
    from repro.chip.topology import fleet_spec
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import transformer as tfm
    from repro.serve.batcher import ContinuousBatcher, make_trace, summarize
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.fleet import (FleetPod, FleetRouter, PodCosts,
                                   VirtualClock, run_virtual_trace)

    n = 12 if quick else 16
    cfg = get_smoke_config("qwen3_14b")
    mesh = make_local_mesh()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    costs = PodCosts(decode_step_s=1e-3, tick_overhead_s=5e-4)

    def engine(chunk):
        return ServeEngine(cfg, mesh, params, ServeConfig(
            batch=4, cache_capacity=128, prefill_chunk=chunk))

    def trace():
        # long prefill, short decode: the traffic disaggregation feeds on
        return make_trace(n, vocab_size=cfg.vocab_size,
                          prompt_lens=(64, 96, 80, 64),
                          max_new=(4, 8, 6, 8))

    bad = []
    out: dict = {"arch": "qwen3_14b (smoke)", "requests": n, "pods": 4}

    # -- gate 1: one-mixed-pod fleet == direct batcher -------------------
    fr1 = FleetRouter([FleetPod(engine(16), "mixed", costs=costs)])
    got = fr1.run(trace())
    vc = VirtualClock()
    ref = run_virtual_trace(ContinuousBatcher(engine(16), vc), trace(),
                            costs)
    direct = summarize(ref, vc.t)
    same = (len(got) == len(ref)
            and all(a.rid == b.rid and np.array_equal(a.tokens, b.tokens)
                    and abs(a.finish_s - b.finish_s) < 1e-9
                    for a, b in zip(got, ref))
            and all(fr1.summary()[k] == v for k, v in direct.items()))
    out["single_pod_equivalent"] = bool(same)
    print(f"  1-pod fleet == direct batcher: {'OK' if same else 'BROKEN'}")
    if not same:
        bad.append("one-mixed-pod fleet is not value-identical to the "
                   "direct ContinuousBatcher")

    # -- gate 2: disaggregation beats mixed replicas ---------------------
    fl = fleet_spec(ipu_pod4_hbm(), 4)

    def run_fleet(roles, fleet=None):
        pods = [FleetPod(engine(128 if r == "prefill" else 16), r,
                         costs=costs) for r in roles]
        router = FleetRouter(pods, fleet=fleet)
        router.run(trace())
        return router

    mixed = run_fleet(["mixed"] * 4)
    disagg = run_fleet(["prefill", "prefill", "decode", "decode"],
                       fleet=fl)
    ms, ds = mixed.summary(), disagg.summary()
    out["mixed"], out["disagg"] = ms, ds
    print(f"  mixed x4   gen={ms['gen_tok_s']:8.1f} tok/s "
          f"p99_ttft={ms['p99_ttft_s'] * 1e3:6.1f}ms")
    print(f"  disagg 2+2 gen={ds['gen_tok_s']:8.1f} tok/s "
          f"p99_ttft={ds['p99_ttft_s'] * 1e3:6.1f}ms "
          f"({ds['migrations']} migrations, "
          f"{ds['planned_migration_s'] * 1e3:.3f}ms planned)")
    if not (ds["gen_tok_s"] > ms["gen_tok_s"]
            and ds["p99_ttft_s"] < ms["p99_ttft_s"]):
        bad.append(f"disaggregation does not strictly beat mixed "
                   f"replicas (gen {ds['gen_tok_s']} vs "
                   f"{ms['gen_tok_s']}, p99 ttft {ds['p99_ttft_s']} vs "
                   f"{ms['p99_ttft_s']})")

    # -- gate 3: migration charged, sim within 2x of plan ----------------
    res = simulate_fleet_traffic(fl, disagg.migration_events)
    sim = sum(f - at for f, (_, at, _, _) in
              zip(res.finish, disagg.migration_events))
    ratio = sim / max(disagg.planned_migration_s, 1e-12)
    out["migration_sim_plan_ratio"] = round(ratio, 4)
    print(f"  migration sim/plan ratio: {ratio:.3f}")
    if disagg.planned_migration_s <= 0:
        bad.append("fleet-priced migrations were free")
    if not 0.5 <= ratio <= 2.0:
        bad.append(f"migration sim/plan ratio {ratio:.3f} outside 2x")

    out["sweep"] = fleet_sweep(smoke=True, prompt_len=1024)
    _write_json("BENCH_fleet.json", out)
    if bad:
        raise RuntimeError("; ".join(bad))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="small model set + core sections only")
    ap.add_argument("--section", action="append", default=None,
                    metavar="NAME",
                    help="run only the named section(s); 'compile' is an "
                         "alias for bench_compile (repeatable)")
    ap.add_argument("--out-dir", default=None, metavar="DIR",
                    help="directory for every section's JSON/CSV "
                         "(default: experiments/bench/; BENCH_*.json are "
                         "also copied to the repo root)")
    args = ap.parse_args(argv)
    if args.out_dir:
        common.set_out_dir(args.out_dir)
    quick = args.quick
    t0 = time.time()
    from benchmarks import paper_figs, roofline, validate_paper

    sections = [
        ("bench_compile", lambda: bench_compile(quick)),
        ("bench_serve", lambda: bench_serve(quick)),
        ("bench_pipeline", lambda: bench_pipeline(quick)),
        ("bench_fusion", lambda: bench_fusion(quick)),
        ("bench_hybrid", lambda: bench_hybrid(quick)),
        ("bench_memtier", lambda: bench_memtier(quick)),
        ("bench_fleet", lambda: bench_fleet(quick)),
        ("fig_fusion", paper_figs.fig_fusion),
        ("fig12_costmodel", paper_figs.fig12_costmodel),
        ("fig16_compile_time", paper_figs.fig16_compile_time),
        ("fig17_latency", paper_figs.fig17_latency),
        ("fig18_breakdown", paper_figs.fig18_breakdown),
        ("fig19_hbm_sweep", paper_figs.fig19_20_hbm_sweep),
        ("fig21_topology", paper_figs.fig21_topology),
        ("fig22_noc_sweep", paper_figs.fig22_noc_sweep),
        ("fig23_cores", paper_figs.fig23_cores),
        ("fig24_topology", paper_figs.fig24_topology),
        ("fig24_training", paper_figs.fig24_training),
        ("simulator_validation", paper_figs.simulator_validation),
        ("validate_paper", validate_paper.validate),
        ("roofline_table", roofline.roofline_table),
        ("multipod_table", roofline.multi_pod_table),
    ]
    if args.section:
        aliases = {"compile": "bench_compile", "serve": "bench_serve",
                   "pipeline": "bench_pipeline", "fusion": "bench_fusion",
                   "hybrid": "bench_hybrid", "memtier": "bench_memtier",
                   "fleet": "bench_fleet"}
        wanted = {aliases.get(s, s) for s in args.section}
        known = {name for name, _ in sections}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"known: {sorted(known)}")
        sections = [s for s in sections if s[0] in wanted]
    elif quick:
        keep = {"bench_compile", "bench_serve", "bench_pipeline",
                "bench_fusion", "bench_hybrid", "bench_memtier",
                "bench_fleet",
                "fig12_costmodel",
                "fig18_breakdown", "fig24_topology", "validate_paper",
                "roofline_table"}
        sections = [s for s in sections if s[0] in keep]

    failed = []
    for name, fn in sections:
        print(f"\n===== {name} =====")
        t = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"[ERROR] {name}: {type(e).__name__}: {e}")
            failed.append(name)
        print(f"----- {name} done in {time.time() - t:.1f}s")
    print(f"\nall benchmarks finished in {time.time() - t0:.1f}s; "
          f"outputs in {os.path.normpath(common.out_dir())}/")
    if failed:
        print(f"FAILED sections: {', '.join(failed)}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
