"""Benchmark driver: one section per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--quick] [--section NAME ...]``."""

from __future__ import annotations

import argparse
import json
import time


def bench_compile(quick: bool = False) -> None:
    """Per-design compile wall-clock + plan quality -> BENCH_compile.json.

    Tracks the pass-pipeline refactor's speedup in the bench trajectory:
    cold compile (plan cache cleared), cached recompile, and the plan's
    ``total_time`` for every §6.1 design on the paper's decode shape.
    """
    from repro.chip.config import ipu_pod4_hbm
    from repro.configs import get_config
    from repro.core.elk import compile_model
    from repro.core.pipeline import clear_plan_cache

    chip = ipu_pod4_hbm()
    models = ("opt_30b",) if quick else ("opt_30b", "llama2_13b")
    out: dict = {"chip": chip.name, "batch": 32, "seq": 2048,
                 "phase": "decode", "models": {}}
    for model in models:
        cfg = get_config(model)
        rec = {}
        for design in ("Basic", "Static", "ELK-Dyn", "ELK-Full"):
            clear_plan_cache()
            t0 = time.perf_counter()
            plan = compile_model(cfg, chip, batch=32, seq=2048,
                                 phase="decode", design=design)
            cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                          design=design)
            warm = time.perf_counter() - t0
            rec[design] = {"compile_s": round(cold, 4),
                           "cached_compile_s": round(warm, 6),
                           "plan_total_time": plan.total_time}
            print(f"  {model:12s} {design:9s} compile={cold:7.2f}s "
                  f"cached={warm*1e3:7.3f}ms plan={plan.total_time:.6g}s")
        out["models"][model] = rec
    with open("BENCH_compile.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote BENCH_compile.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small model set + core sections only")
    ap.add_argument("--section", action="append", default=None,
                    metavar="NAME",
                    help="run only the named section(s); 'compile' is an "
                         "alias for bench_compile (repeatable)")
    args = ap.parse_args(argv)
    quick = args.quick
    t0 = time.time()
    from benchmarks import paper_figs, roofline, validate_paper

    sections = [
        ("bench_compile", lambda: bench_compile(quick)),
        ("fig12_costmodel", paper_figs.fig12_costmodel),
        ("fig16_compile_time", paper_figs.fig16_compile_time),
        ("fig17_latency", paper_figs.fig17_latency),
        ("fig18_breakdown", paper_figs.fig18_breakdown),
        ("fig19_hbm_sweep", paper_figs.fig19_20_hbm_sweep),
        ("fig21_topology", paper_figs.fig21_topology),
        ("fig22_noc_sweep", paper_figs.fig22_noc_sweep),
        ("fig23_cores", paper_figs.fig23_cores),
        ("fig24_topology", paper_figs.fig24_topology),
        ("fig24_training", paper_figs.fig24_training),
        ("simulator_validation", paper_figs.simulator_validation),
        ("validate_paper", validate_paper.validate),
        ("roofline_table", roofline.roofline_table),
        ("multipod_table", roofline.multi_pod_table),
    ]
    if args.section:
        aliases = {"compile": "bench_compile"}
        wanted = {aliases.get(s, s) for s in args.section}
        known = {name for name, _ in sections}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"known: {sorted(known)}")
        sections = [s for s in sections if s[0] in wanted]
    elif quick:
        keep = {"bench_compile", "fig12_costmodel", "fig18_breakdown",
                "fig24_topology", "validate_paper", "roofline_table"}
        sections = [s for s in sections if s[0] in keep]

    failed = []
    for name, fn in sections:
        print(f"\n===== {name} =====")
        t = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"[ERROR] {name}: {type(e).__name__}: {e}")
            failed.append(name)
        print(f"----- {name} done in {time.time() - t:.1f}s")
    print(f"\nall benchmarks finished in {time.time() - t0:.1f}s; "
          f"CSVs in experiments/bench/")
    if failed:
        print(f"FAILED sections: {', '.join(failed)}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
