"""Validation against the paper's own claims (§6.2/§6.3).

Checked (tolerances documented in EXPERIMENTS.md §Validation):
  * design ordering Basic <= Static <= ELK-Dyn <= ELK-Full <= Ideal,
  * ELK-Full >= 90% of Ideal (paper: 94.84% mean),
  * HBM-utilization ordering Basic < ELK-Full <= Ideal-neighborhood,
  * mean preload-reorder edit distance is small (paper: 2.9 steps).
"""

from __future__ import annotations

from benchmarks.common import default_chip, emit
from repro.configs import get_config
from repro.core.elk import compare_designs


def validate(models=("llama2_13b", "opt_30b"), batch=32, seq=2048
             ) -> list[dict]:
    rows = []
    ok_all = True
    chip = default_chip()
    for model in models:
        plans = compare_designs(get_config(model), chip, batch=batch,
                                seq=seq, phase="decode")
        t = {d: p.total_time for d, p in plans.items()}
        checks = {
            "ordering": t["Basic"] >= t["Static"] * 0.999
            and t["Static"] >= t["ELK-Dyn"] * 0.999
            and t["ELK-Dyn"] >= t["ELK-Full"] * 0.999
            and t["ELK-Full"] >= t["Ideal"] * 0.999,
            "full_vs_ideal_90pct": t["Ideal"] / t["ELK-Full"] >= 0.90,
            "hbm_util_ordering": plans["Basic"].util.hbm
            <= plans["ELK-Full"].util.hbm + 1e-6,
            "edit_distance_small":
                plans["ELK-Full"].edit_distance() <= 6.0,
        }
        ok_all &= all(checks.values())
        rows.append({"model": model,
                     "full_vs_ideal": round(t["Ideal"] / t["ELK-Full"], 4),
                     "basic_slowdown": round(t["Basic"] / t["ELK-Full"], 3),
                     "static_slowdown": round(t["Static"] / t["ELK-Full"],
                                              3),
                     **{k: str(v) for k, v in checks.items()}})
    emit("validate_paper", rows)
    if not ok_all:
        raise SystemExit("paper-claim validation FAILED")
    return rows


if __name__ == "__main__":
    validate()
