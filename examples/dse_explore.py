"""Design-space exploration with the ICCA simulator toolkit (paper §6.4):
sweep HBM bandwidth, NoC bandwidth and topology, reproduce the paper's
insight that the two bandwidths must scale together.

    PYTHONPATH=src python examples/dse_explore.py
"""

from repro.chip.config import TB, ipu_pod4_hbm
from repro.configs import get_config
from repro.core.elk import compile_model

cfg = get_config("llama2_13b")

print("HBM bandwidth sweep (ELK-Full per-token latency, ms):")
for bw in (2, 4, 8, 16, 32):
    chip = ipu_pod4_hbm(hbm_bw=bw * TB)
    p = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                      design="ELK-Full", max_orders=4)
    print(f"  hbm={bw:2d} TB/s -> {p.total_time*1e3:7.3f} ms  "
          f"(hbm util {p.util.hbm:5.1%})")

print("\nNoC x HBM joint sweep (the 'scale together' insight):")
base = ipu_pod4_hbm()
for noc_scale in (0.5, 1.0, 2.0):
    row = f"  noc x{noc_scale:3.1f}: "
    for bw in (8, 16, 32):
        chip = base.scaled(link_bw=base.link_bw * noc_scale,
                           hbm_bw=bw * TB)
        p = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                          design="ELK-Full", max_orders=4)
        row += f"hbm{bw:2d}TB={p.total_time*1e3:7.3f}ms  "
    print(row)

print("\ntopology comparison:")
for topo in ("all2all", "mesh2d"):
    chip = ipu_pod4_hbm(topology=topo)
    p = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                      design="ELK-Full", max_orders=4)
    print(f"  {topo:8s}: {p.total_time*1e3:7.3f} ms "
          f"(noc util {p.util.interconnect:5.1%})")
