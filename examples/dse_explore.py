"""Design-space exploration with the ICCA simulator toolkit (paper §6.4):
sweep HBM bandwidth, NoC bandwidth and interconnect topology, reproduce the
paper's insight that the two bandwidths must scale together and the §6.4
topology-sensitivity story.

    PYTHONPATH=src python examples/dse_explore.py [--model M] \
        [--topologies all2all,mesh2d,...] [--csv PATH] [--fast]

``--fast`` truncates the model to two layers and skips the bandwidth
sweeps — the CI smoke configuration.
"""

from __future__ import annotations

import argparse
import csv
import dataclasses
import os

from repro.chip.config import TB, ipu_pod4_hbm
from repro.chip.dse import topology_sweep
from repro.chip.topology import TOPOLOGIES
from repro.configs import get_config
from repro.core.elk import compile_model

DEFAULT_TOPOLOGIES = ("all2all", "mesh2d", "torus2d", "ring", "hier_pod")


def bandwidth_sweeps(cfg, max_orders: int) -> None:
    print("HBM bandwidth sweep (ELK-Full per-token latency, ms):")
    for bw in (2, 4, 8, 16, 32):
        chip = ipu_pod4_hbm(hbm_bw=bw * TB)
        p = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                          design="ELK-Full", max_orders=max_orders)
        print(f"  hbm={bw:2d} TB/s -> {p.total_time*1e3:7.3f} ms  "
              f"(hbm util {p.util.hbm:5.1%})")

    print("\nNoC x HBM joint sweep (the 'scale together' insight):")
    base = ipu_pod4_hbm()
    for noc_scale in (0.5, 1.0, 2.0):
        row = f"  noc x{noc_scale:3.1f}: "
        for bw in (8, 16, 32):
            chip = base.scaled(link_bw=base.link_bw * noc_scale,
                               hbm_bw=bw * TB)
            p = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                              design="ELK-Full", max_orders=max_orders)
            row += f"hbm{bw:2d}TB={p.total_time*1e3:7.3f}ms  "
        print(row)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="llama2_13b")
    ap.add_argument("--topologies",
                    default=",".join(DEFAULT_TOPOLOGIES),
                    help="comma-separated topology registry keys "
                         f"(known: {sorted(TOPOLOGIES)})")
    ap.add_argument("--csv",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "experiments", "bench",
                                         "dse_topology.csv"),
                    help="where to write the topology-sweep CSV (kept "
                         "distinct from the benchmark-owned "
                         "fig24_topology.csv so smoke runs don't clobber "
                         "the paper-figure data)")
    ap.add_argument("--fast", action="store_true",
                    help="2-layer truncation, topology sweep only (CI smoke)")
    args = ap.parse_args(argv)

    topologies = [s for s in args.topologies.split(",") if s]
    if not topologies:
        ap.error("no topologies given")
    for topo in topologies:
        if topo not in TOPOLOGIES:
            ap.error(f"unknown topology {topo!r}; known: {sorted(TOPOLOGIES)}")

    cfg = get_config(args.model)
    max_orders = 2 if args.fast else 4
    if args.fast:
        cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 2))
    else:
        bandwidth_sweeps(cfg, max_orders)

    print("\ntopology sweep:")
    rows = topology_sweep(cfg, topologies, designs=("ELK-Full",),
                          max_orders=max_orders)
    for r in rows:
        print(f"  {r['topology']:8s}: {r['latency_ms']:8.3f} ms plan / "
              f"{r['sim_ms']:8.3f} ms sim  (noc util {r['noc_util']:5.1%}, "
              f"delivery {r['delivery_tbps']:6.2f} TB/s)")
    os.makedirs(os.path.dirname(os.path.abspath(args.csv)), exist_ok=True)
    with open(args.csv, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
