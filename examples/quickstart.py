"""Quickstart: compile a model with ELK, inspect the plan, compare the
paper's five designs, and run the event simulator — all on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.chip.config import ipu_pod4_hbm
from repro.chip.simulator import simulate
from repro.configs import get_config
from repro.core.elk import compare_designs, compile_model

chip = ipu_pod4_hbm()                      # the paper's emulator target
cfg = get_config("llama2_13b")

# --- one ELK-Full compile -------------------------------------------------
plan = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                     design="ELK-Full")
print(f"ELK-Full plan for {cfg.name}: {len(plan.graph.ops)} ops, "
      f"per-token latency {plan.total_time*1e3:.2f} ms")
print(f"  mean preload number : {plan.mean_preload_number:.1f}")
print(f"  reorder edit dist   : {plan.edit_distance():.2f}")
print(f"  HBM util {plan.util.hbm:.1%} | NoC util "
      f"{plan.util.interconnect:.1%} | {plan.util.achieved_tflops:.0f} "
      f"TFLOPS")

# --- the §6.1 ablation ------------------------------------------------------
plans = compare_designs(cfg, chip, batch=32, seq=2048, phase="decode")
ideal = plans["Ideal"].total_time
print("\ndesign comparison (paper Fig. 17):")
for name, p in plans.items():
    print(f"  {name:9s} {p.total_time*1e3:7.3f} ms   "
          f"{ideal/p.total_time:6.1%} of Ideal")

# --- independent event-driven simulation -----------------------------------
import dataclasses
small = dataclasses.replace(cfg, num_layers=2)
sim_plan = compile_model(small, chip, batch=32, seq=2048, phase="decode",
                         design="ELK-Dyn")
res = simulate(sim_plan, chip)
print(f"\nevent simulator cross-check (2-layer model): "
      f"plan={sim_plan.total_time*1e3:.3f} ms, sim={res.total_time*1e3:.3f} "
      f"ms")
