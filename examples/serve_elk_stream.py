"""Serve a model with the ELK weight-streaming engine and verify the
gather-ahead window (the paper's preload number, chosen by the faithful
ELK scheduler) changes scheduling but never results.

    PYTHONPATH=src python examples/serve_elk_stream.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.integration import pod_plan
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.serve.engine import ServeConfig, ServeEngine

ARCH = "llama4_maverick_400b_a17b"      # MoE: experts are late-bound preloads

# 1. ask the faithful ELK compiler for the pod-level knobs (full config)
knobs = pod_plan(get_config(ARCH), batch=8, seq=2048, phase="decode")
print(f"ELK scheduler decisions for {ARCH}:")
print(f"  prefetch_depth (preload number) = {knobs.prefetch_depth}")
print(f"  resident_fraction (preload-state f) = "
      f"{knobs.resident_fraction:.3f} -> fsdp={knobs.fsdp}")

# 2. serve the smoke-scale config on CPU with those knobs
cfg = get_smoke_config(ARCH)
mesh = make_local_mesh()
params = tfm.init_params(jax.random.PRNGKey(0), cfg)
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                             cfg.vocab_size)

outs = {}
for mode in ("gspmd", "elk_stream"):
    eng = ServeEngine(cfg, mesh, params, ServeConfig(
        batch=4, cache_capacity=64, mode=mode,
        prefetch_depth=knobs.prefetch_depth))
    outs[mode] = eng.generate(prompts, steps=8)
    print(f"{mode:11s}: {outs[mode][0, -8:].tolist()}")

assert bool(jnp.all(outs["gspmd"] == outs["elk_stream"]))
print("gather-ahead streaming == resident baseline: exact match")
