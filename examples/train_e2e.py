"""End-to-end training driver: train a ~small model a few hundred steps on
CPU with the full production stack (sharded step, grad accumulation,
checkpointing, fault-tolerant trainer) and verify the loss drops.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""

import argparse
import os
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_14b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = make_local_mesh()
    wd = tempfile.mkdtemp(prefix="repro_train_")
    trainer = Trainer(
        cfg,
        DataConfig(batch=8, seq=64, vocab_size=cfg.vocab_size),
        AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
        TrainerConfig(workdir=wd, total_steps=args.steps, ckpt_every=50,
                      grad_accum=2),
        mesh,
    )
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) for "
          f"{args.steps} steps; workdir {wd}")
    log = trainer.run()
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({log[-1]['step_time']*1e3:.0f} ms/step)")
    assert last < first, "loss did not decrease"
    print("checkpoints:", sorted(os.listdir(os.path.join(wd, "ckpt"))))


if __name__ == "__main__":
    main()
