import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
from repro.configs import ARCH_IDS
from repro.launch.dryrun import run_cell, run_cell_accounting
from repro.launch.specs import SHAPES

which = sys.argv[1] if len(sys.argv) > 1 else "all"
for arch in ARCH_IDS:
    for shape in SHAPES:
        if which in ("all", "prod"):
            for mesh in ("single", "multi"):
                try:
                    run_cell(arch, shape, mesh)
                except Exception as e:
                    print(f"[FATAL] {arch} {shape} {mesh}: {e}", flush=True)
        if which in ("all", "acct"):
            try:
                run_cell_accounting(arch, shape, "single")
            except Exception as e:
                print(f"[FATAL acct] {arch} {shape}: {e}", flush=True)
print("SWEEP DONE", flush=True)
