"""Atomic, async, resharding-on-restore checkpoint store.

Layout: one ``.npy`` per pytree leaf (keyed by '/'-joined path) plus a
``manifest.json`` with the tree structure and step.  Writes go to a temp
directory and are renamed into place — a crashed writer can never corrupt
the latest checkpoint (the fault-tolerance contract the trainer relies on).

``save_async`` runs serialization on a worker thread so the train loop
only blocks for the device->host copy; ``restore`` takes target shardings
and ``jax.device_put``s each leaf, so a checkpoint written on one mesh
restores onto any other (elastic scaling across pod counts).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def path_str(path):
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "/".join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_str(path)] = np.asarray(leaf)
    return flat


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    # -- write -----------------------------------------------------------
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, tree: PyTree) -> str:
        flat = _flatten(tree)   # device->host copy happens here
        return self._write(step, flat)

    def save_async(self, step: int, tree: PyTree) -> None:
        self.wait()
        flat = _flatten(tree)   # blocking part: device->host
        self._worker = threading.Thread(
            target=self._write, args=(step, flat), daemon=True)
        self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> str:
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in flat.items():
            fname = key.replace("/", "__") + ".npy"
            dtype = str(arr.dtype)
            if arr.dtype.kind not in "fiub":   # bf16 etc: store raw bits
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {"file": fname,
                                       "dtype": dtype,
                                       "shape": list(arr.shape)}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)    # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- read ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, _MANIFEST)):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of ``like``; leaves are device_put
        with ``shardings`` (resharding across mesh shapes is free here —
        device_put lays each host array out per the target sharding)."""
        d = self._dir(step)
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)

        flat_like = _flatten_paths(like)
        shard_flat = _flatten_paths(shardings) if shardings is not None \
            else {k: None for k in flat_like}
        out = {}
        for key in flat_like:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(d, meta["file"]))
            if str(arr.dtype) != meta["dtype"]:    # raw-bit storage
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(
                    ml_dtypes, meta["dtype"], meta["dtype"])))
            sh = shard_flat.get(key)
            out[key] = jax.device_put(arr, sh) if sh is not None \
                else jax.numpy.asarray(arr)
        return _unflatten_like(like, out)


def _flatten_paths(tree: PyTree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        flat["/".join(parts)] = leaf
    return flat


def _unflatten_like(like: PyTree, flat: dict[str, Any]) -> PyTree:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in paths_leaves:
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        leaves.append(flat["/".join(parts)])
    return jax.tree_util.tree_unflatten(treedef, leaves)
