from repro.chip.config import ChipConfig, ipu_mk2, ipu_pod4_hbm, tpu_v5e_pod, tpu_v5e_pod_hier, tpu_v5e_vmem  # noqa: F401
from repro.chip.topology import TOPOLOGIES, ChipView, LinkClass, TopologyModel, build_topology  # noqa: F401
