"""ICCA chip hardware descriptions (paper §2.1, §6.1).

A ``ChipConfig`` is the hardware vocabulary shared by the ELK compiler core,
the event-driven simulator, and the TPU integration layer.  All bandwidths are
bytes/s, capacities bytes, compute FLOP/s.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Topology = Literal["all2all", "mesh2d"]

KB = 1024
MB = 1024 * KB
GB = 1024 * MB
TB = 1024 * GB


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    """One ICCA chip (or a multi-chip pod treated as one flat core pool)."""

    name: str
    num_cores: int
    sram_per_core: int                 # bytes of local scratchpad per core
    core_flops: float                  # peak FLOP/s of one core (matmul)
    core_flops_vector: float           # peak FLOP/s of one core (non-matmul)
    sram_bw_per_core: float            # local SRAM read bandwidth per core
    link_bw: float                     # one inter-core link (per direction)
    topology: Topology = "all2all"
    num_chips: int = 1                 # multi-chip pod: NoC topology is per-chip
    mesh_dims: tuple[int, int] = (0, 0)    # per-chip mesh; (0,0) -> near-square
    hbm_bw: float = 0.0                # aggregate off-chip bandwidth
    hbm_controllers: int = 4
    hbm_latency: float = 1e-6          # per-request latency (s)
    link_latency: float = 5e-7         # per-hop latency (s)
    # Per-core reserved bytes (paper §5: 8KB inter-core receive buffer).
    reserved_per_core: int = 8 * KB
    # IPU-style SRAM port contention: remote reads block local compute (§2.3 ③,
    # footnote 2).  False for chips whose local memory is dual-ported.
    sram_port_blocking: bool = True

    # ---- derived -----------------------------------------------------------
    @property
    def total_sram(self) -> int:
        return self.num_cores * (self.sram_per_core - self.reserved_per_core)

    @property
    def usable_sram_per_core(self) -> int:
        return self.sram_per_core - self.reserved_per_core

    @property
    def total_flops(self) -> float:
        return self.num_cores * self.core_flops

    @property
    def interconnect_bw(self) -> float:
        """Aggregate all-to-all interconnect bandwidth (paper: 1472*5.5GB/s)."""
        return self.num_cores * self.link_bw

    @property
    def cores_per_chip(self) -> int:
        return self.num_cores // max(self.num_chips, 1)

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """Per-chip mesh grid (paper §6.1 simulates 4 chips, each its own NoC)."""
        if self.topology != "mesh2d":
            raise ValueError("mesh_shape on non-mesh chip")
        if self.mesh_dims != (0, 0):
            return self.mesh_dims
        # near-square factorization of the per-chip core count
        n = self.cores_per_chip
        r = int(n ** 0.5)
        while n % r:
            r -= 1
        return (r, n // r)

    # ---- NoC traffic model (paper §5 mapping strategies) --------------------
    # all2all: each core drives one 5.5GB/s link at a time => capacity N*link,
    #   every transfer is 1 "hop".
    # mesh2d: each core talks to up to 4 neighbors simultaneously (paper §6.1)
    #   => capacity 4*N*link, but a transfer consumes one link per hop.
    #   Dimension-order routing maps partition dims to mesh dims, so
    #   compute-shift rotations / ring reductions are neighbor hops (1);
    #   the data-distribution phase fetches within a group mapped to a mesh
    #   dim (~2 hops); HBM controllers sit on the grid edges, so preload
    #   traffic crosses (rows+cols)/4 links on average.
    @property
    def noc_capacity(self) -> float:
        if self.topology == "all2all":
            return self.num_cores * self.link_bw
        return 4 * self.num_cores * self.link_bw

    @property
    def preload_hops(self) -> float:
        if self.topology == "all2all":
            return 1.0
        r, c = self.mesh_shape
        return max((r + c) / 4.0, 1.0)

    @property
    def dist_hops(self) -> float:
        return 1.0 if self.topology == "all2all" else 2.0

    @property
    def preload_noc_bw(self) -> float:
        """Effective HBM-controller->cores delivery bandwidth over the NoC."""
        return self.noc_capacity / self.preload_hops

    def noc_occupancy(self, exec_bytes: float, preload_bytes: float,
                      dist_bytes: float = 0.0) -> float:
        """Seconds of aggregate link capacity consumed by a traffic mix."""
        weighted = (exec_bytes + preload_bytes * self.preload_hops
                    + dist_bytes * self.dist_hops)
        return weighted / self.noc_capacity

    def scaled(self, **kw) -> "ChipConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Reference chips
# ---------------------------------------------------------------------------

def ipu_mk2() -> ChipConfig:
    """One Graphcore IPU MK2 (paper §2.1): 1472 cores x 624KB, 5.5GB/s links."""
    return ChipConfig(
        name="ipu-mk2",
        num_cores=1472,
        sram_per_core=624 * KB,
        # 250 TFLOPS/chip fp16 => ~170 GFLOPS/core for matmul; vector ~1/32.
        core_flops=250e12 / 1472,
        core_flops_vector=31.2e12 / 1472,
        sram_bw_per_core=128 / 8 * 1.325e9,  # 128 bits/cycle @ 1.325GHz (§2.3)
        link_bw=5.5 * GB,
        topology="all2all",
        hbm_bw=0.0,
    )


def ipu_pod4_hbm(hbm_bw: float = 16 * TB, topology: Topology = "all2all") -> ChipConfig:
    """The paper's emulator target: IPU-POD4 (4xMK2 = 5888 cores, 3.5GB SRAM)
    + 4 HBM3E modules per chip => 16TB/s aggregate (paper §6.1)."""
    return ChipConfig(
        name="ipu-pod4-hbm",
        num_cores=5888,
        sram_per_core=624 * KB,
        core_flops=1000e12 / 5888,          # 1 PFLOPS pod for MatMul (paper §6.3)
        core_flops_vector=4 * 31.2e12 / 5888,
        sram_bw_per_core=128 / 8 * 1.325e9,  # 128 bits/cycle @1.325GHz (paper §2.3)
        link_bw=5.5 * GB,
        topology=topology,
        num_chips=4,
        hbm_bw=hbm_bw,
        hbm_controllers=16,                  # 4 modules x 4 chips
        sram_port_blocking=True,
    )


def tpu_v5e_pod(num_chips: int = 256) -> ChipConfig:
    """A TPU v5e pod read as one ICCA chip (DESIGN.md §3A): chips=cores,
    ICI=inter-core links, per-chip HBM='SRAM', host DRAM/the pod's own sharded
    weight store = 'off-chip'.  Constants per the assignment: 197 TFLOP/s bf16,
    819 GB/s HBM, ~50 GB/s/link ICI."""
    return ChipConfig(
        name=f"tpu-v5e-{num_chips}",
        num_cores=num_chips,
        sram_per_core=16 * GB,              # per-chip HBM as the local store
        core_flops=197e12,
        core_flops_vector=197e12 / 16,
        sram_bw_per_core=819 * GB,
        link_bw=50 * GB,
        topology="mesh2d",
        mesh_dims=(16, num_chips // 16) if num_chips % 16 == 0 else (0, 0),
        hbm_bw=819 * GB * num_chips * 0.1,  # host->HBM aggregate (DCN-limited)
        hbm_controllers=num_chips // 4,
        link_latency=1e-6,
        sram_port_blocking=False,           # HBM not blocked by ICI traffic
        reserved_per_core=0,
    )


def tpu_v5e_vmem() -> ChipConfig:
    """One TPU v5e chip read as an ICCA chip at the VMEM level (DESIGN.md §3B):
    the single TensorCore's VMEM is the on-chip memory, HBM the off-chip one.
    Used by core/integration.vmem_plan() to pick Pallas block shapes."""
    return ChipConfig(
        name="tpu-v5e-vmem",
        num_cores=1,
        sram_per_core=128 * MB,
        core_flops=197e12,
        core_flops_vector=197e12 / 16,
        sram_bw_per_core=40 * TB,           # VMEM->MXU feed bandwidth (approx)
        link_bw=819 * GB,                   # 'interconnect' = HBM bus
        topology="all2all",
        hbm_bw=819 * GB,
        hbm_controllers=1,
        sram_port_blocking=False,
        reserved_per_core=0,
    )
