"""ICCA chip hardware descriptions (paper §2.1, §6.1).

A ``ChipConfig`` is the hardware vocabulary shared by the ELK compiler core,
the event-driven simulator, and the TPU integration layer.  All bandwidths are
bytes/s, capacities bytes, compute FLOP/s.
"""

from __future__ import annotations

import dataclasses

from repro.chip.topology import (TOPOLOGIES, ChipView, TopologyModel,
                                 build_topology, near_square_grid)

# Registry key into chip/topology.TOPOLOGIES ("all2all", "mesh2d",
# "torus2d", "ring", "hier_pod", ...); kept as a plain str alias so the
# pre-refactor annotations stay valid.
Topology = str

KB = 1024
MB = 1024 * KB
GB = 1024 * MB
TB = 1024 * GB


@dataclasses.dataclass(frozen=True)
class MemoryTier:
    """One level of the chip's memory hierarchy (DESIGN.md §10).

    Ordered fastest (tier 0 = the cores' SRAM) to slowest (the unbounded
    backing store, HBM/DRAM).  ``capacity <= 0`` marks the backing tier:
    it holds everything that is not staged closer to the cores.
    """
    name: str
    capacity: int          # aggregate bytes; <= 0 = unbounded backing store
    bandwidth: float       # aggregate bytes/s toward the cores
    latency: float = 0.0   # per-request latency (s)
    controllers: int = 1

    @property
    def unbounded(self) -> bool:
        return self.capacity <= 0


# An ordered MemoryTier list; plain tuple alias so specs stay hashable and
# usable inside the frozen ChipConfig / cache keys.
MemorySpec = tuple

# Tier names synthesized from the legacy scalar fields.  Custom tiers in
# ``mem_tiers`` may use any other name; these two are always rebuilt from
# ``sram_per_core``/``hbm_*`` so the scalars stay the single source of
# truth (and ``scaled()``/``dataclasses.replace`` can never desync them).
_RESERVED_TIER_NAMES = ("sram", "hbm")


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    """One ICCA chip (or a multi-chip pod treated as one flat core pool)."""

    name: str
    num_cores: int
    sram_per_core: int                 # bytes of local scratchpad per core
    core_flops: float                  # peak FLOP/s of one core (matmul)
    core_flops_vector: float           # peak FLOP/s of one core (non-matmul)
    sram_bw_per_core: float            # local SRAM read bandwidth per core
    link_bw: float                     # one inter-core link (per direction)
    topology: Topology = "all2all"
    num_chips: int = 1                 # multi-chip pod: NoC topology is per-chip
    mesh_dims: tuple[int, int] = (0, 0)    # per-chip mesh; (0,0) -> near-square
    # hier_pod: inter-chip tier = inter_links_per_chip gateway links per chip,
    # each at inter_bw_ratio * link_bw (a distinct, slower link class).
    inter_bw_ratio: float = 0.25
    inter_links_per_chip: int = 8
    hbm_bw: float = 0.0                # aggregate off-chip bandwidth
    hbm_controllers: int = 4
    hbm_latency: float = 1e-6          # per-request latency (s)
    link_latency: float = 5e-7         # per-hop latency (s)
    # Per-core reserved bytes (paper §5: 8KB inter-core receive buffer).
    reserved_per_core: int = 8 * KB
    # IPU-style SRAM port contention: remote reads block local compute (§2.3 ③,
    # footnote 2).  False for chips whose local memory is dual-ported.
    sram_port_blocking: bool = True
    # Ordered memory hierarchy (DESIGN.md §10).  ``__post_init__`` always
    # canonicalizes this to  (sram, *middle tiers, hbm?)  where the "sram"
    # and "hbm" tiers are synthesized from the scalar fields above (hbm only
    # when hbm_bw > 0) and the middle tiers (e.g. stacked DRAM) are kept from
    # whatever was passed in.  Callers only ever *add* middle tiers — via
    # ``with_stacked_dram()`` or by passing an existing ``mem_tiers`` through
    # ``scaled()`` — so legacy scalar updates can never desync the spec.
    mem_tiers: MemorySpec = ()

    def __post_init__(self):
        # fail at the construction site, not at the first chip.topo access
        # deep inside a compile
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"known: {sorted(TOPOLOGIES)}")
        if self.topology == "hier_pod" and (
                self.inter_bw_ratio <= 0 or self.inter_links_per_chip <= 0):
            raise ValueError(
                "hier_pod needs inter_bw_ratio > 0 and "
                f"inter_links_per_chip > 0, got {self.inter_bw_ratio!r} / "
                f"{self.inter_links_per_chip!r}")
        middles = tuple(t for t in self.mem_tiers
                        if t.name not in _RESERVED_TIER_NAMES)
        names = [t.name for t in middles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate memory tier names: {names}")
        for t in middles:
            if t.capacity <= 0 or t.bandwidth <= 0:
                raise ValueError(
                    f"middle memory tier {t.name!r} needs capacity > 0 and "
                    f"bandwidth > 0 (only the synthesized backing tier is "
                    f"unbounded), got {t.capacity!r} / {t.bandwidth!r}")
        tiers = (MemoryTier("sram", self.total_sram,
                            self.num_cores * self.sram_bw_per_core,
                            0.0, self.num_cores),) + middles
        if self.hbm_bw > 0:
            tiers += (MemoryTier("hbm", 0, self.hbm_bw, self.hbm_latency,
                                 self.hbm_controllers),)
        object.__setattr__(self, "mem_tiers", tiers)

    # ---- derived -----------------------------------------------------------
    @property
    def total_sram(self) -> int:
        return self.num_cores * (self.sram_per_core - self.reserved_per_core)

    @property
    def usable_sram_per_core(self) -> int:
        return self.sram_per_core - self.reserved_per_core

    @property
    def total_flops(self) -> float:
        return self.num_cores * self.core_flops

    @property
    def interconnect_bw(self) -> float:
        """Aggregate all-to-all interconnect bandwidth (paper: 1472*5.5GB/s)."""
        return self.num_cores * self.link_bw

    @property
    def cores_per_chip(self) -> int:
        return self.num_cores // max(self.num_chips, 1)

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """Per-chip mesh grid (paper §6.1 simulates 4 chips, each its own NoC)."""
        if self.topology not in ("mesh2d", "torus2d"):
            raise ValueError("mesh_shape on non-mesh chip")
        if self.mesh_dims != (0, 0):
            return self.mesh_dims
        return near_square_grid(self.cores_per_chip)

    # ---- NoC traffic model --------------------------------------------------
    # Delegated to the pluggable topology subsystem (chip/topology.py): the
    # bound TopologyModel owns routing hop weights, per-link-class capacities
    # and collective cost shapes; the properties below are the back-compat
    # scalar vocabulary the compiler core and simulator consume.
    @property
    def topo(self) -> TopologyModel:
        # memoized on the instance: hashing the whole dataclass per lookup
        # is too slow for the allocator/scheduler hot paths
        t = self.__dict__.get("_topo")
        if t is None:
            t = build_topology(self)
            object.__setattr__(self, "_topo", t)
        return t

    @property
    def topo_signature(self) -> tuple:
        """Hashable topology identity for compile-pipeline cache keys."""
        return self.topo.signature()

    # ---- memory hierarchy (DESIGN.md §10) ----------------------------------
    @property
    def mem_signature(self) -> tuple:
        """Hashable memory-hierarchy identity for compile-pipeline cache
        keys (the tier-list analogue of ``topo_signature``)."""
        s = self.__dict__.get("_mem_sig")
        if s is None:
            s = tuple((t.name, t.capacity, t.bandwidth, t.latency,
                       t.controllers) for t in self.mem_tiers)
            object.__setattr__(self, "_mem_sig", s)
        return s

    @property
    def backing_tier(self) -> int:
        """Index of the tier that holds everything not staged closer to the
        cores: the unbounded hbm tier when present, else the last tier."""
        return len(self.mem_tiers) - 1

    @property
    def staging_tiers(self) -> tuple[int, ...]:
        """Indices of capacity-bounded off-core tiers weight blocks can be
        staged into (everything strictly between SRAM and the backing
        store; empty for the default two-tier chips)."""
        last = self.backing_tier
        return tuple(k for k in range(1, len(self.mem_tiers))
                     if k != last and not self.mem_tiers[k].unbounded)

    def tier_capacity_per_core(self, tier: int) -> int:
        """One core's share of a tier's capacity (tier 0 = the usable local
        scratchpad; deeper tiers are chip-shared, split evenly)."""
        if tier <= 0:
            return self.usable_sram_per_core
        t = self.mem_tiers[tier]
        return t.capacity // max(self.num_cores, 1) if t.capacity > 0 else 0

    @property
    def noc_capacity(self) -> float:
        return self.topo.total_capacity

    @property
    def preload_hops(self) -> float:
        return self.topo.preload_hops

    @property
    def dist_hops(self) -> float:
        return self.topo.dist_hops

    @property
    def preload_noc_bw(self) -> float:
        """Effective HBM-controller->cores delivery bandwidth over the NoC."""
        return self.topo.preload_delivery_bw

    def noc_occupancy(self, exec_bytes: float, preload_bytes: float,
                      dist_bytes: float = 0.0) -> float:
        """Seconds of link capacity consumed by a traffic mix (bottleneck
        tier for multi-class topologies)."""
        return self.topo.occupancy(exec_bytes, preload_bytes, dist_bytes)

    def chip_view(self, width: int = 1) -> ChipView:
        """One member chip of this pod + the inter-chip tier a pipeline
        stage boundary crosses (DESIGN.md §7).  ``width > 1`` tags the view
        as one shard of a tensor-parallel stage spanning ``width`` member
        chips (DESIGN.md §9)."""
        return self.topo.chip_view(width)

    def collective_time(self, kind: str, nbytes: float, width: int,
                        link_class: str | None = None) -> float:
        """Ring-collective time among ``width`` member chips (DESIGN.md §9)."""
        return self.topo.collective_time(kind, nbytes, width, link_class)

    def scaled(self, mem_divide: float = 1, **kw) -> "ChipConfig":
        """``dataclasses.replace`` plus memory-hierarchy scaling:
        ``mem_divide=n`` hands out a 1/n share of every middle tier (used by
        ``chip_view()`` to derive one member chip of a pod — the sram/hbm
        tiers rescale automatically from the scalar fields)."""
        if mem_divide != 1:
            src = kw.get("mem_tiers", self.mem_tiers)
            kw["mem_tiers"] = tuple(
                dataclasses.replace(
                    t,
                    capacity=int(t.capacity / mem_divide),
                    bandwidth=t.bandwidth / mem_divide,
                    controllers=max(int(t.controllers / mem_divide), 1))
                for t in src if t.name not in _RESERVED_TIER_NAMES)
        return dataclasses.replace(self, **kw)

    def with_stacked_dram(self, capacity: int = 8 * GB,
                          bandwidth: float = 2 * TB, *,
                          latency: float = 5e-7, controllers: int = 8,
                          name: str = "stacked") -> "ChipConfig":
        """This chip plus a 3D-stacked DRAM tier between SRAM and HBM
        (Voxel/DeepStack direction, DESIGN.md §10) — the sweepable design
        point ``chip/dse.tier_sweep`` explores."""
        tier = MemoryTier(name, capacity, bandwidth, latency, controllers)
        middles = tuple(t for t in self.mem_tiers
                        if t.name not in _RESERVED_TIER_NAMES)
        return dataclasses.replace(self, mem_tiers=middles + (tier,))


# ---------------------------------------------------------------------------
# Reference chips
# ---------------------------------------------------------------------------

def ipu_mk2() -> ChipConfig:
    """One Graphcore IPU MK2 (paper §2.1): 1472 cores x 624KB, 5.5GB/s links."""
    return ChipConfig(
        name="ipu-mk2",
        num_cores=1472,
        sram_per_core=624 * KB,
        # 250 TFLOPS/chip fp16 => ~170 GFLOPS/core for matmul; vector ~1/32.
        core_flops=250e12 / 1472,
        core_flops_vector=31.2e12 / 1472,
        sram_bw_per_core=128 / 8 * 1.325e9,  # 128 bits/cycle @ 1.325GHz (§2.3)
        link_bw=5.5 * GB,
        topology="all2all",
        hbm_bw=0.0,
    )


def ipu_pod4_hbm(hbm_bw: float = 16 * TB, topology: Topology = "all2all") -> ChipConfig:
    """The paper's emulator target: IPU-POD4 (4xMK2 = 5888 cores, 3.5GB SRAM)
    + 4 HBM3E modules per chip => 16TB/s aggregate (paper §6.1)."""
    return ChipConfig(
        name="ipu-pod4-hbm",
        num_cores=5888,
        sram_per_core=624 * KB,
        core_flops=1000e12 / 5888,          # 1 PFLOPS pod for MatMul (paper §6.3)
        core_flops_vector=4 * 31.2e12 / 5888,
        sram_bw_per_core=128 / 8 * 1.325e9,  # 128 bits/cycle @1.325GHz (paper §2.3)
        link_bw=5.5 * GB,
        topology=topology,
        num_chips=4,
        hbm_bw=hbm_bw,
        hbm_controllers=16,                  # 4 modules x 4 chips
        sram_port_blocking=True,
    )


def tpu_v5e_pod(num_chips: int = 256) -> ChipConfig:
    """A TPU v5e pod read as one ICCA chip (DESIGN.md §3A): chips=cores,
    ICI=inter-core links, per-chip HBM='SRAM', host DRAM/the pod's own sharded
    weight store = 'off-chip'.  Constants per the assignment: 197 TFLOP/s bf16,
    819 GB/s HBM, ~50 GB/s/link ICI."""
    return ChipConfig(
        name=f"tpu-v5e-{num_chips}",
        num_cores=num_chips,
        sram_per_core=16 * GB,              # per-chip HBM as the local store
        core_flops=197e12,
        core_flops_vector=197e12 / 16,
        sram_bw_per_core=819 * GB,
        link_bw=50 * GB,
        topology="mesh2d",
        mesh_dims=(16, num_chips // 16) if num_chips % 16 == 0 else (0, 0),
        hbm_bw=819 * GB * num_chips * 0.1,  # host->HBM aggregate (DCN-limited)
        hbm_controllers=num_chips // 4,
        link_latency=1e-6,
        sram_port_blocking=False,           # HBM not blocked by ICI traffic
        reserved_per_core=0,
    )


def tpu_v5e_pod_hier(num_chips: int = 256, groups: int = 4) -> ChipConfig:
    """The TPU pod read as a hierarchical multi-chip pod (DESIGN.md §7):
    ``groups`` islands of chips, each island an all2all ICI domain, behind a
    thinner DCN-like tier.  This is the pod model the pipeline-parallel
    planner partitions the layer stack over."""
    flat = tpu_v5e_pod(num_chips)
    return flat.scaled(
        name=f"tpu-v5e-{num_chips}x{groups}",
        topology="hier_pod",
        num_chips=groups,
        mesh_dims=(0, 0),
        inter_bw_ratio=0.1,               # DCN egress ~5 GB/s per link
        inter_links_per_chip=max(num_chips // (4 * groups), 1))


def tpu_v5e_vmem() -> ChipConfig:
    """One TPU v5e chip read as an ICCA chip at the VMEM level (DESIGN.md §3B):
    the single TensorCore's VMEM is the on-chip memory, HBM the off-chip one.
    Used by core/integration.vmem_plan() to pick Pallas block shapes."""
    return ChipConfig(
        name="tpu-v5e-vmem",
        num_cores=1,
        sram_per_core=128 * MB,
        core_flops=197e12,
        core_flops_vector=197e12 / 16,
        sram_bw_per_core=40 * TB,           # VMEM->MXU feed bandwidth (approx)
        link_bw=819 * GB,                   # 'interconnect' = HBM bus
        topology="all2all",
        hbm_bw=819 * GB,
        hbm_controllers=1,
        sram_port_blocking=False,
        reserved_per_core=0,
    )
