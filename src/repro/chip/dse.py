"""Topology design-space sweep (paper §6.4), shared by
``examples/dse_explore.py`` and ``benchmarks/paper_figs.fig24_topology``.

One row per (topology, design): the compiled plan's latency plus an
event-simulated latency on a small layer truncation (the simulator
exercises the per-link-class contention the plan estimate approximates),
and the topology's routing summary.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.chip.config import ChipConfig, ipu_pod4_hbm


def topology_sweep(cfg, topologies: Sequence[str], *, batch: int = 32,
                   seq: int = 2048, designs: Sequence[str] = ("ELK-Full",),
                   max_orders: int = 4, sim_layers: int = 2,
                   chip_factory: Callable[..., ChipConfig] = ipu_pod4_hbm,
                   ) -> list[dict]:
    from repro.chip.simulator import simulate
    from repro.core.baselines import build_plan
    from repro.core.elk import compare_designs
    from repro.core.graph import build_graph
    from repro.core.pipeline import CompileContext

    sim_cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers,
                                                      sim_layers))
    g = build_graph(sim_cfg, batch=batch, seq=seq, phase="decode")
    rows = []
    for topo in topologies:
        chip = chip_factory(topology=topo)
        ctx = CompileContext(chip)   # curves/windows shared across designs
        plans = compare_designs(cfg, chip, batch=batch, seq=seq,
                                phase="decode", designs=tuple(designs),
                                max_orders=max_orders, ctx=ctx)
        t = chip.topo
        for d, p in plans.items():
            # simulate *this design's* plan on the truncation, so each row
            # pairs a plan estimate with its own simulated counterpart.
            # Ideal is a roofline with no preload plans — the simulator
            # would see zero preload traffic, so no sim column for it.
            sim = (simulate(build_plan(g, chip, d, max_orders=max_orders,
                                       ctx=ctx), chip)
                   if d != "Ideal" else None)
            rows.append({
                "topology": topo, "design": d,
                "latency_ms": round(p.total_time * 1e3, 3),
                "sim_ms": round(sim.total_time * 1e3, 3) if sim else "",
                "sim_layers": sim_cfg.num_layers if sim else "",
                "noc_util": round(p.util.interconnect, 4),
                "preload_hops": round(t.preload_hops, 2),
                "delivery_tbps": round(t.preload_delivery_bw / 1e12, 3),
                "bisection_tbps": round(t.bisection_bw / 1e12, 3),
                "mean_preload_number": round(p.mean_preload_number, 2),
            })
    return rows
