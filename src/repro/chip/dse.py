"""Design-space sweeps, shared by ``examples/dse_explore.py`` and the
benchmark sections.

* :func:`topology_sweep` (paper §6.4) — one row per (topology, design):
  the compiled plan's latency plus an event-simulated latency on a small
  layer truncation (the simulator exercises the per-link-class contention
  the plan estimate approximates), and the topology's routing summary.
* :func:`pipeline_sweep` (DESIGN.md §7) — stage-count x chip-count sweep
  of the pipeline-parallel pod planner: steady-state interval vs the
  replicated single-chip baseline, with a simulated interval on a layer
  truncation to validate the planner's estimate.
* :func:`hybrid_sweep` (DESIGN.md §9) — topology x model sweep of the
  joint (cut x width x replicas x microbatch) hybrid planner against the
  pure pipeline it is never allowed to lose to, with a simulated interval
  validating the hybrid plan (collectives + replica servers included).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.chip.config import ChipConfig, ipu_pod4_hbm


def topology_sweep(cfg, topologies: Sequence[str], *, batch: int = 32,
                   seq: int = 2048, designs: Sequence[str] = ("ELK-Full",),
                   max_orders: int = 4, sim_layers: int = 2,
                   chip_factory: Callable[..., ChipConfig] = ipu_pod4_hbm,
                   ) -> list[dict]:
    from repro.chip.simulator import simulate
    from repro.core.baselines import build_plan
    from repro.core.elk import compare_designs
    from repro.core.graph import build_graph
    from repro.core.pipeline import CompileContext

    sim_cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers,
                                                      sim_layers))
    g = build_graph(sim_cfg, batch=batch, seq=seq, phase="decode")
    rows = []
    for topo in topologies:
        chip = chip_factory(topology=topo)
        ctx = CompileContext(chip)   # curves/windows shared across designs
        plans = compare_designs(cfg, chip, batch=batch, seq=seq,
                                phase="decode", designs=tuple(designs),
                                max_orders=max_orders, ctx=ctx)
        t = chip.topo
        for d, p in plans.items():
            # simulate *this design's* plan on the truncation, so each row
            # pairs a plan estimate with its own simulated counterpart.
            # Ideal is a roofline with no preload plans — the simulator
            # would see zero preload traffic, so no sim column for it.
            sim = (simulate(build_plan(g, chip, d, max_orders=max_orders,
                                       ctx=ctx), chip)
                   if d != "Ideal" else None)
            rows.append({
                "topology": topo, "design": d,
                "latency_ms": round(p.total_time * 1e3, 3),
                "sim_ms": round(sim.total_time * 1e3, 3) if sim else "",
                "sim_layers": sim_cfg.num_layers if sim else "",
                "noc_util": round(p.util.interconnect, 4),
                "preload_hops": round(t.preload_hops, 2),
                "delivery_tbps": round(t.preload_delivery_bw / 1e12, 3),
                "bisection_tbps": round(t.bisection_bw / 1e12, 3),
                "mean_preload_number": round(p.mean_preload_number, 2),
            })
    return rows


def scale_pod(base: ChipConfig, num_chips: int) -> ChipConfig:
    """Scale a pod config to ``num_chips`` chips, keeping per-chip
    resources (cores, HBM share, controllers) fixed."""
    n0 = max(base.num_chips, 1)
    per_cores = base.cores_per_chip
    per_hbm = base.hbm_bw / n0
    per_ctrl = max(base.hbm_controllers // n0, 1)
    return base.scaled(name=f"{base.name}-x{num_chips}",
                       num_chips=num_chips,
                       num_cores=per_cores * num_chips,
                       hbm_bw=per_hbm * num_chips,
                       hbm_controllers=per_ctrl * num_chips)


def pipeline_sweep(cfg, *, num_chips_list: Sequence[int] = (1, 2, 4),
                   stage_counts: Optional[Sequence[int]] = None,
                   batch: int = 32, seq: int = 2048,
                   design: str = "ELK-Full", max_orders: int = 4,
                   sim_layers: int = 8,
                   chip_factory: Callable[..., ChipConfig] = ipu_pod4_hbm,
                   ) -> list[dict]:
    """Stage-count x chip-count sweep of the pipeline-parallel planner.

    Each row pairs the planner's steady-state decode interval for the whole
    running batch (``microbatches * bottleneck interval``) with the
    replicated single-chip baseline (every chip serves ``batch/num_chips``
    requests with a full model replica) and with an event-simulated
    interval on a ``sim_layers`` truncation — the planner estimate the CI
    gate holds to within 2x.
    """
    from repro.chip.simulator import simulate_pipeline
    from repro.core.pipeline_pod import plan_pipeline, replicated_plan

    base = chip_factory(topology="hier_pod")
    rows = []
    for n in num_chips_list:
        pod = scale_pod(base, n)
        for s in (stage_counts or (n,)):
            if s > n or s > cfg.num_layers:
                continue
            pp = plan_pipeline(cfg, pod, batch=batch, seq=seq,
                               design=design, num_stages=s,
                               max_orders=max_orders)
            rep = replicated_plan(cfg, pod, batch=batch, seq=seq,
                                  design=design, max_orders=max_orders)
            # simulate on a truncation: exact (non-extrapolated) stage
            # plans, same per-link-class contention machinery
            sim_cfg = dataclasses.replace(
                cfg, num_layers=min(cfg.num_layers, max(sim_layers, s)))
            pps = plan_pipeline(sim_cfg, pod, batch=batch, seq=seq,
                                design=design, num_stages=s,
                                max_orders=max_orders)
            sim = simulate_pipeline(pps, pod)
            rows.append({
                "model": cfg.name, "num_chips": n, "stages": pp.num_stages,
                "microbatch": pp.microbatch,
                "microbatches": pp.microbatches,
                "cuts": "/".join(str(st.layers[1]) for st in pp.stages),
                "interval_ms": round(pp.interval * 1e3, 3),
                "batch_interval_ms": round(pp.batch_interval * 1e3, 3),
                "fill_ms": round(pp.fill_time * 1e3, 3),
                "replicated_ms": round(rep.total_time * 1e3, 3),
                "speedup_vs_replicated": round(
                    rep.total_time / pp.batch_interval, 3)
                if pp.batch_interval else "",
                "sim_layers": sim_cfg.num_layers,
                "sim_interval_ms": round(sim.interval * 1e3, 3),
                "plan_sim_ratio": round(sim.interval / pps.interval, 3)
                if pps.interval else "",
            })
    return rows


def hybrid_sweep(models: Sequence[str] = ("opt_30b",), *,
                 topologies: Sequence[str] = ("all2all", "mesh2d", "torus2d",
                                              "ring", "hier_pod"),
                 num_chips: int = 4, batch: int = 32, seq: int = 2048,
                 design: str = "ELK-Full", max_orders: int = 4,
                 sim_layers: int = 8,
                 microbatches: Optional[int] = None,
                 chip_factory: Callable[..., ChipConfig] = ipu_pod4_hbm,
                 ) -> list[dict]:
    """Topology x model sweep of the hybrid planner (DESIGN.md §9).

    Each row pairs the pure-pipeline plan with the hybrid plan on the same
    ``sim_layers`` truncation (exact stage plans, one memoized compile
    context per (model, topology) shared across all widths and microbatch
    candidates) and event-simulates the hybrid plan — replica servers and
    intra-stage collectives included.  Two gates ride on the rows:
    per-request hybrid time never above pipeline (the planner is
    never-worse by construction, so a violation is a regression) and the
    simulated steady interval within 2x of the planner's.
    """
    from repro.chip.simulator import simulate_pipeline
    from repro.configs import get_config
    from repro.core.pipeline_pod import plan_hybrid, plan_pipeline

    rows = []
    for model in models:
        cfg = get_config(model)
        sim_cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers,
                                                          sim_layers))
        for topo in topologies:
            pod = scale_pod(chip_factory(topology=topo), num_chips)
            # hybrid first: it plans the pure pipeline internally through
            # the shared context, so the explicit call below hits the cache
            hyb = plan_hybrid(sim_cfg, pod, batch=batch, seq=seq,
                              design=design, max_orders=max_orders,
                              microbatches=microbatches)
            pipe = plan_pipeline(sim_cfg, pod, batch=batch, seq=seq,
                                 design=design, max_orders=max_orders)
            sim = simulate_pipeline(hyb, pod)
            pipe_req = pipe.batch_interval / max(pipe.batch, 1)
            hyb_req = hyb.batch_interval / max(hyb.batch, 1)
            rows.append({
                "model": cfg.name, "topology": topo, "num_chips": num_chips,
                "pipe_interval_ms": round(pipe.interval * 1e3, 3),
                "pipe_batch_interval_ms": round(pipe.batch_interval * 1e3,
                                                3),
                "pipe_req_us": round(pipe_req * 1e6, 3),
                "hybrid_interval_ms": round(hyb.interval * 1e3, 3),
                "hybrid_batch_interval_ms": round(hyb.batch_interval * 1e3,
                                                  3),
                "hybrid_req_us": round(hyb_req * 1e6, 3),
                "hybrid_speedup": round(pipe_req / hyb_req, 3)
                if hyb_req else "",
                "hybrid_won": int(hyb_req < pipe_req),
                "stages": hyb.num_stages,
                "microbatch": hyb.microbatch,
                "microbatches": hyb.microbatches,
                "cuts": "/".join(str(st.layers[1]) for st in hyb.stages),
                "widths": "/".join(str(st.width) for st in hyb.stages),
                "replicas": "/".join(str(st.replicas) for st in hyb.stages),
                "sim_layers": sim_cfg.num_layers,
                "sim_interval_ms": round(sim.interval * 1e3, 3),
                "plan_sim_ratio": round(sim.interval / hyb.interval, 3)
                if hyb.interval else "",
            })
    return rows


def tier_sweep(model: str = "opt_30b", *,
               sizes_gb: Sequence[float] = (4, 8, 16),
               bws_tbps: Sequence[float] = (2, 8, 16),
               num_chips: int = 4, batch: int = 4, seq: int = 2048,
               design: str = "ELK-Full", max_orders: int = 2,
               sim_layers: int = 8,
               chip_factory: Callable[..., ChipConfig] = ipu_pod4_hbm,
               ) -> list[dict]:
    """Stacked-DRAM (size x bandwidth) sweep of the tiered-memory planner
    (DESIGN.md §10).

    The base pod is planned once; every swept row appends a stacked tier
    via :meth:`ChipConfig.with_stacked_dram` and re-plans the same decode
    round.  The tiered planner is never-worse by construction
    (``_prefer_untiered``), so ``round_ms`` can only drop: ``improved``
    marks rows where the stacked tier strictly beat the flat HBM backing
    store, and the event simulator validates the plan estimate on every
    improved row (CI gates on both).
    """
    from repro.chip.config import GB, TB
    from repro.chip.simulator import simulate_pipeline
    from repro.configs import get_config
    from repro.core.pipeline_pod import plan_pipeline

    cfg = get_config(model)
    sim_cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers,
                                                      sim_layers))
    pod = scale_pod(chip_factory(topology="hier_pod"), num_chips)
    base = plan_pipeline(sim_cfg, pod, batch=batch, seq=seq, design=design,
                         max_orders=max_orders)
    base_sim = simulate_pipeline(base, pod)
    rows = [{
        "model": cfg.name, "num_chips": num_chips,
        "tier": "none", "size_gb": "", "bw_tbps": "",
        "round_ms": round(base.batch_interval * 1e3, 4),
        "interval_ms": round(base.interval * 1e3, 4),
        "speedup": 1.0, "improved": 0,
        "staged_mb": 0.0,
        "sim_layers": sim_cfg.num_layers,
        "sim_interval_ms": round(base_sim.interval * 1e3, 4),
        "plan_sim_ratio": round(base_sim.interval / base.interval, 3)
        if base.interval else "",
    }]
    for size in sizes_gb:
        for bw in bws_tbps:
            tiered = pod.with_stacked_dram(int(size * GB), bw * TB)
            pp = plan_pipeline(sim_cfg, tiered, batch=batch, seq=seq,
                               design=design, max_orders=max_orders)
            # the never-worse fallback returns the base pod's (cached) plan
            # object itself — its src_tier indices refer to the *two-tier*
            # chip, so count/simulate it against the chip it was planned on
            if pp is base:
                sim, staged = base_sim, 0
            else:
                sim = simulate_pipeline(pp, tiered)
                backing = len(tiered.chip_view().chip.mem_tiers) - 1
                staged = sum(d.preload_plan.hbm_bytes
                             for st in pp.stages for d in st.plan.decisions
                             if d.preload_plan is not None
                             and 0 < d.src_tier < backing)
            rows.append({
                "model": cfg.name, "num_chips": num_chips,
                "tier": "stacked", "size_gb": size, "bw_tbps": bw,
                "round_ms": round(pp.batch_interval * 1e3, 4),
                "interval_ms": round(pp.interval * 1e3, 4),
                "speedup": round(base.batch_interval / pp.batch_interval, 4)
                if pp.batch_interval else "",
                "improved": int(pp.batch_interval < base.batch_interval),
                "staged_mb": round(staged / 1e6, 1),
                "sim_layers": sim_cfg.num_layers,
                "sim_interval_ms": round(sim.interval * 1e3, 4),
                "plan_sim_ratio": round(sim.interval / pp.interval, 3)
                if pp.interval else "",
            })
    return rows


def kv_offload_sweep(model: str = "opt_30b", *,
                     sizes_gb: Sequence[float] = (16, 32, 64, 128),
                     bw_tbps: float = 2.0, slots: int = 4,
                     cache_capacity: int = 2048,
                     kv_dtype: str = "bfloat16",
                     smoke: bool = False) -> list[dict]:
    """Backing-tier size sweep of the serve-side KV offload design space
    (DESIGN.md §11): for each stacked-DRAM size on an SRAM-only chip
    (``ipu_mk2`` — no unbounded HBM, so the whole hierarchy is finite),
    the static per-request budget (``tier_kv_capacity``), the admission
    multiplier K (``tier_kv_oversub``), one slot-ring spill/refill time
    (``AnalyticCostModel.spill_time``), and the rings the tier holds —
    how much serving concurrency each GB of stacked capacity buys."""
    from repro.chip.config import GB, TB, ipu_mk2
    from repro.configs import get_config, get_smoke_config
    from repro.core.cost_model import AnalyticCostModel
    from repro.serve.engine import (_tier_bytes_left, kv_ring_bytes,
                                    tier_kv_capacity, tier_kv_oversub)

    cfg = get_smoke_config(model) if smoke else get_config(model)
    ring = kv_ring_bytes(cfg, cache_capacity, kv_dtype)
    rows = []
    for size in sizes_gb:
        chip = ipu_mk2().with_stacked_dram(int(size * GB), bw_tbps * TB)
        cap = tier_kv_capacity(cfg, chip, batch=slots, kv_dtype=kv_dtype)
        k = tier_kv_oversub(cfg, chip, slots=slots,
                            cache_capacity=cache_capacity,
                            kv_dtype=kv_dtype)
        spill = AnalyticCostModel(chip).spill_time(ring, 0,
                                                   chip.backing_tier)
        rows.append({
            "model": cfg.name, "slots": slots,
            "cache_capacity": cache_capacity,
            "size_gb": size, "bw_tbps": bw_tbps,
            "kv_tokens_per_req": cap,
            "rings": int(_tier_bytes_left(cfg, chip) // max(ring, 1)),
            "oversub_k": round(k, 3),
            "ring_mb": round(ring / 1e6, 3),
            "slot_spill_us": round(spill * 1e6, 3),
        })
    return rows


def fleet_sweep(model: str = "opt_30b", *,
                num_pods: int = 4,
                n_prefill_list: Sequence[int] = (1, 2),
                inter_bw_ratios: Sequence[float] = (0.25, 0.0625),
                slots: int = 4, cache_capacity: int = 2048,
                prompt_len: int = 1024, kv_dtype: str = "bfloat16",
                decode_step_s: float = 1e-3,
                smoke: bool = False) -> list[dict]:
    """Fleet disaggregation sweep (DESIGN.md §12): for each prefill/decode
    split of a ``num_pods`` fleet and each inter-pod fabric dilution, the
    predicted steady decode rate and prefill service rate of the split vs
    the same pods run as mixed replicas (``serve.fleet.
    predict_fleet_rates`` under the PodCosts tick pricing), and what one
    KV-ring migration costs over that fabric (``FleetSpec.
    migration_time``) against the decode work it unlocks.  A split "wins"
    when it beats the mixed baseline on prefill service rate without
    giving up generated-token rate — the router only disaggregates when
    this row says so."""
    from repro.chip.config import ipu_pod4_hbm
    from repro.chip.topology import fleet_spec
    from repro.configs import get_config, get_smoke_config
    from repro.serve.engine import PREFILL_SAT, kv_ring_bytes
    from repro.serve.fleet import PodCosts, predict_fleet_rates

    cfg = get_smoke_config(model) if smoke else get_config(model)
    pod = ipu_pod4_hbm()
    ring = kv_ring_bytes(cfg, cache_capacity, kv_dtype)
    costs = PodCosts(decode_step_s=decode_step_s,
                     tick_overhead_s=0.5 * decode_step_s)
    rows = []
    for ratio in inter_bw_ratios:
        fl = dataclasses.replace(fleet_spec(pod, num_pods),
                                 inter_pod_bw=0.0, inter_bw_ratio=ratio)
        mig = fl.migration_time(ring, 0, num_pods - 1)
        for n_pf in n_prefill_list:
            if not 0 < n_pf < num_pods:
                continue
            r = predict_fleet_rates(
                costs, num_pods=num_pods, n_prefill=n_pf, slots=slots,
                prompt_len=prompt_len, chunk_prefill=PREFILL_SAT)
            # migration overhead per request, amortized over its decode
            # stream on the split's decode pods
            rows.append({
                "model": cfg.name, "num_pods": num_pods,
                "n_prefill": n_pf, "slots": slots,
                "prompt_len": prompt_len,
                "inter_bw_ratio": ratio,
                "ring_mb": round(ring / 1e6, 3),
                "migration_ms": round(mig * 1e3, 4),
                "mixed_gen_tok_s": round(r["mixed_gen_tok_s"], 1),
                "disagg_gen_tok_s": round(r["disagg_gen_tok_s"], 1),
                "mixed_prefill_req_s": round(r["mixed_prefill_req_s"], 2),
                "disagg_prefill_req_s":
                    round(r["disagg_prefill_req_s"], 2),
                "disagg_won": bool(
                    r["disagg_prefill_req_s"] > r["mixed_prefill_req_s"]
                    and r["disagg_gen_tok_s"] >= r["mixed_gen_tok_s"]),
            })
    return rows
