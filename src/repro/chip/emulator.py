"""jnp dataflow emulator: numerical correctness of ELK partition plans.

The paper's emulator ran plans on a physical IPU-POD4; this container has
no IPU, so the timing role went to ``chip/simulator.py`` and the
*numerical* role lives here: execute a partition plan's tile dataflow with
explicit per-core tiles and explicit inter-core movement (broadcast at
preload / compute-shift rotation at execute), then assert the result
matches a plain jnp reference.

This validates the semantic claims a partition plan makes: the dim splits
cover the iteration space exactly, the preload fraction + distribution
phase reconstruct the full shared tile on every core, and reduction over
split contraction dims recombines to the true product.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.partition import ExecPlan, PreloadPlan


def emulate_matmul_plan(x: jax.Array, w: jax.Array, plan: ExecPlan,
                        preload: PreloadPlan | None = None) -> jax.Array:
    """Execute (M,K)@(K,N) under ``plan.split`` = (sm, sn, sk) core grid.

    Core (i, j, l) computes X[i-rows, l-cols] @ W[l-rows, j-cols]; partial
    results reduce over l.  The shared-tensor movement is emulated
    explicitly: each core's copy of its W tile starts as the ``preload.frac``
    slice (what HBM controllers broadcast) and is completed by the
    data-distribution phase (concatenating the peers' slices) — so a wrong
    fraction/bookkeeping breaks numerics, not just a cost estimate."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    sm, sn, sk = (tuple(plan.split) + (1, 1, 1))[:3]

    def splits(dim: int, parts: int) -> list[slice]:
        step = -(-dim // parts)
        return [slice(i * step, min((i + 1) * step, dim))
                for i in range(parts)]

    ms, ns, ks = splits(m, sm), splits(n, sn), splits(k, sk)
    out = jnp.zeros((m, n), jnp.float32)
    frac = preload.frac if preload is not None else 1.0

    for i, mslc in enumerate(ms):
        for j, nslc in enumerate(ns):
            for l, kslc in enumerate(ks):
                x_tile = x[mslc, kslc].astype(jnp.float32)
                w_tile_full = w[kslc, nslc].astype(jnp.float32)
                # --- preload state: core holds a frac-slice of its tile
                rows = w_tile_full.shape[0]
                own = max(int(round(rows * frac)), 1)
                preloaded = w_tile_full[:own]
                # --- distribution phase: fetch the rest from peers
                # (emulated as an explicit concat of the missing rows)
                if own < rows:
                    fetched = w_tile_full[own:]
                    w_tile = jnp.concatenate([preloaded, fetched], axis=0)
                else:
                    w_tile = preloaded[:rows]
                # --- execute: optional compute-shift rotation in chunks
                r = max(plan.chunk, 1)
                acc = jnp.zeros((x_tile.shape[0], w_tile.shape[1]),
                                jnp.float32)
                csz = -(-w_tile.shape[0] // r)
                for c in range(r):
                    rs = slice(c * csz, min((c + 1) * csz, w_tile.shape[0]))
                    if rs.start >= w_tile.shape[0]:
                        break
                    acc = acc + x_tile[:, rs] @ w_tile[rs]
                out = out.at[mslc, nslc].add(acc)
    return out.astype(x.dtype)


def check_plan_numerics(plan: ExecPlan, preload: PreloadPlan | None = None,
                        m: int = 64, n: int = 48, k: int = 32,
                        seed: int = 0, rtol: float = 2e-2) -> float:
    """Random (m,k)@(k,n) under the plan vs jnp reference; returns max err.

    ``rtol`` is relative to the reference magnitude: the check is
    ``max|got - ref| <= rtol * (max|ref| + 1)``."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    got = emulate_matmul_plan(x, w, plan, preload)
    ref = x @ w
    err = float(jnp.max(jnp.abs(got - ref)))
    bound = rtol * float(jnp.max(jnp.abs(ref)) + 1.0)
    assert err <= bound, (
        f"plan dataflow diverges from reference: max abs err {err:.3e} > "
        f"rtol*max|ref| bound {bound:.3e}")
    return err
