"""Event-driven ICCA chip simulator (paper §5 "Simulation framework").

Simulates the execution of an ELK ``ExecutionPlan`` over contended
resources, independently of the scheduler's own cost estimates:

* **Memory tiers** — every off-core tier of ``chip.mem_tiers`` (HBM,
  stacked DRAM, ...) is its own contended resource serving *its* preloads
  one at a time in preload order (§4.5 rule 2, per controller group),
  gated by on-chip space and MoE routing deps; each request pays the
  tier's per-request latency.  A two-tier chip reduces to the single
  serial HBM server of the original model.
* **NoC** — processor-sharing fluid model over the topology's *link
  classes* (``chip.topo.classes``): flat topologies expose one
  ``intra`` pool; the hierarchical pod adds a slower ``inter`` tier.
  Each flow (preload delivery, data distribution, execution-time
  rotation) carries per-class weighted byte-hops from
  ``topo.flow_weights``; flows active on a class split that class's
  capacity, and a flow completes when *every* class it crosses has
  drained — congestion on one tier stretches only the flows that cross
  it (the paper's contention ②/③, now per tier).  Transfers additionally
  pay per-hop ``link_latency`` before bytes start flowing, matching the
  analytic cost model's ``volume/bw + hops*latency`` vocabulary.
* **Cores** — execute ops sequentially; an op's execute phase cannot run
  faster than its rotation traffic allows.  (Rotation *serial* latency is
  already inside ``ExecPlan.time`` via ``AnalyticCostModel.rot_time``, so
  the rotation flow charges contention only.)

Outputs everything Figures 17-24 read: total latency, the Fig-18(a)
four-way breakdown, HBM/NoC utilization, achieved TFLOPS.  The simulator
is also the DSE vehicle (§6.4): scale ``ChipConfig`` fields or swap the
topology and re-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional

from repro.chip.config import ChipConfig
from repro.core.graph import OpGraph
from repro.core.plan import (Breakdown, ExecutionPlan, OpTiming, Utilization)

if TYPE_CHECKING:
    from repro.core.pipeline_pod import PipelinePlan

_EPS = 1e-12


@dataclasses.dataclass
class _Flow:
    kind: str               # "preload" | "dist" | "rot"
    rem: dict               # link-class name -> weighted byte-hops remaining
    demand: dict            # link-class name -> byte-hops/s drain-rate cap
    latency: float = 0.0    # per-hop pipeline-fill latency not yet elapsed

    def done(self) -> bool:
        return self.latency <= _EPS and all(v <= _EPS
                                            for v in self.rem.values())


@dataclasses.dataclass
class SimResult:
    total_time: float
    breakdown: Breakdown
    util: Utilization
    op_exec_end: list


def simulate(plan: ExecutionPlan, chip: ChipConfig,
             hbm_bw: Optional[float] = None) -> SimResult:
    graph = plan.graph
    n = len(graph.ops)
    hbm_bw = hbm_bw if hbm_bw is not None else chip.hbm_bw
    topo = chip.topo
    caps = {lc.name: lc.capacity for lc in topo.classes}
    cap_total = topo.total_capacity
    cap_mem = chip.usable_sram_per_core
    tiers = chip.mem_tiers
    last_tier = len(tiers) - 1

    pi = plan.preload_order
    dec = {d.op_idx: d for d in plan.decisions}

    def src_tier(j: int) -> int:
        k = dec[j].src_tier
        return k if 0 <= k <= last_tier else last_tier

    op_tiers = {src_tier(j) for j in range(n)}

    def mk_flow(kind: str, nbytes: float, payload_demand: float,
                latency: float) -> _Flow:
        weights = topo.flow_weights(kind)
        # zero-byte flows keep their class entries: an active phase occupies
        # its share of each class it maps onto until the phase completes
        # (processor-sharing semantics inherited from the single-pool model)
        rem = {c: nbytes * w for c, w in weights.items() if w > 0.0}
        # demand is in byte-hop units: a payload-bytes/s cap times the hop
        # weight of the class (so an uncontended transfer drains in
        # bytes/payload_demand seconds, matching the scheduler estimate)
        demand = {c: payload_demand * w for c, w in weights.items()
                  if w > 0.0}
        return _Flow(kind, rem, demand,
                     latency if nbytes > 0 else 0.0)

    # --- state ----------------------------------------------------------
    t = 0.0
    next_pre = 0                       # index into pi
    pre_done = [False] * n
    pre_started = [False] * n          # streaming on some tier server
    exe_done = [-1.0] * n
    space_used = 0.0
    cur = 0                            # next op to execute
    # one serial preload server per source tier (§4.5 rule 2 per
    # controller group); two-tier chips have exactly one server, the
    # original single-HBM state machine
    srv_op: dict[int, int] = {}        # tier -> op currently streaming
    srv_flow: dict[int, Optional[_Flow]] = {}   # NoC side of each preload
    srv_left: dict[int, float] = {}    # tier time remaining (s at full bw)
    exe_flow: Optional[_Flow] = None   # dist or rot flow of current op
    exe_left = 0.0                     # pure-compute seconds remaining
    exe_phase = "idle"                 # idle | dist | run
    # accounting
    busy_hbm = 0.0
    busy_exec = 0.0
    overlap = 0.0
    noc_bytes_served = 0.0

    def preload_space(j: int) -> float:
        p = dec[j].preload_plan
        return p.space if p else 0.0

    def exec_space(j: int) -> float:
        return dec[j].exec_plan.space

    def can_start_preload(j: int) -> bool:
        if graph.ops[j].preload_dep >= 0 and \
                exe_done[graph.ops[j].preload_dep] < 0:
            return False
        return space_used + preload_space(j) <= cap_mem + _EPS

    def tier_service_time(p, k: int) -> float:
        """Tier-side roofline of one preload request (per-request latency +
        volume at the tier's aggregate bandwidth; the ``hbm_bw`` argument
        still overrides the backing tier for DSE-style sweeps)."""
        if not (p and p.hbm_bytes) or k <= 0:
            return 0.0
        if k == last_tier and tiers[last_tier].unbounded:
            return (p.hbm_bytes / hbm_bw + chip.hbm_latency) if hbm_bw else 0.0
        tk = tiers[k]
        return (p.hbm_bytes / tk.bandwidth + tk.latency) if tk.bandwidth \
            else 0.0

    def start_next_preload(force: bool = False):
        nonlocal next_pre, space_used
        # each tier serves one preload at a time (§4.5 rule 2; clobbering a
        # streaming preload leaked its space and deadlocked the sim); scan
        # pi from the head so every tier picks *its* ops in preload order
        while next_pre < n and (pre_done[pi[next_pre]]
                                or pre_started[pi[next_pre]]):
            next_pre += 1
        if len(srv_op) >= len(op_tiers):
            return                     # every source tier already busy
        m = next_pre
        while m < n:
            j = pi[m]
            if pre_done[j] or pre_started[j]:
                m += 1
                continue
            if exe_done[j] >= 0:       # already executed (tiny op, no data)
                pre_done[j] = True
                if m == next_pre:
                    next_pre += 1
                m += 1
                continue
            k = src_tier(j)
            if k in srv_op:
                # this op's tier is busy; later ops on *other* tiers may
                # still start (their chains run in parallel)
                m += 1
                continue
            if not can_start_preload(j):
                # ``force`` models streaming-through under space pressure:
                # when the whole chip is otherwise stalled (execution waits
                # on this preload chain and nothing else is active), the
                # hardware streams the tile through space freed as the
                # blocking residents execute; the fluid accounting lets
                # ``space_used`` transiently exceed the cap instead of
                # wedging.  Routing deps are never forced.  Space is
                # claimed strictly in preload order: a space-blocked op
                # stops the scan for every tier.
                if not force or (graph.ops[j].preload_dep >= 0 and
                                 exe_done[graph.ops[j].preload_dep] < 0):
                    return
            p = dec[j].preload_plan
            srv_op[k] = j
            # per-request tier latency + volume roofline (bugfix: the seed
            # simulator never charged hbm_latency/link_latency at all)
            srv_left[k] = tier_service_time(p, k)
            nbytes = p.noc_preload_bytes if p else 0.0
            srv_flow[k] = mk_flow("preload", nbytes,
                                  topo.preload_delivery_bw,
                                  topo.preload_latency)
            space_used += preload_space(j)
            pre_started[j] = True
            if m == next_pre:
                next_pre += 1
            if force or len(srv_op) >= len(op_tiers):
                return
            m += 1

    def start_exec():
        nonlocal exe_flow, exe_left, exe_phase, space_used
        if cur >= n or exe_phase != "idle" or not pre_done[cur]:
            return
        d = dec[cur]
        p = d.preload_plan
        space_used += exec_space(cur) - (preload_space(cur))
        if p and p.noc_dist_bytes > 0:
            exe_phase = "dist"
            exe_flow = mk_flow("dist", p.noc_dist_bytes, math.inf,
                               topo.dist_latency)
        else:
            _enter_run()

    def _enter_run():
        nonlocal exe_phase, exe_flow, exe_left
        d = dec[cur]
        exe_phase = "run"
        exe_left = d.exec_plan.time
        rot = d.exec_plan.noc_exec_bytes
        exe_flow = mk_flow("rot", float(rot), math.inf, 0.0) if rot else None

    start_next_preload()
    start_exec()

    guard = 0
    while cur < n and guard < 400 * n + 20000:
        guard += 1
        if exe_phase == "idle" and not srv_op:
            # deadlock-or-done check: try to make progress
            start_next_preload()
            start_exec()
            if exe_phase == "idle" and not srv_op:
                # nothing active: advance by marking next preload done
                if next_pre >= n and cur < n and not pre_done[cur]:
                    pre_done[cur] = True     # defensive: zero-data op
                    start_exec()
                    continue
                if exe_phase == "idle" and next_pre < n:
                    # space-blocked with nothing draining: stream the next
                    # preload through (see start_next_preload)
                    start_next_preload(force=True)
                    if srv_op:
                        continue
                if exe_phase == "idle":
                    break

        # per-link-class processor sharing: every active phase occupies its
        # share of each class it maps onto for the phase's whole lifetime
        flows = [f for f in srv_flow.values() if f is not None]
        if exe_flow is not None:
            flows.append(exe_flow)
        nact: dict = {}
        for f in flows:
            for c in f.rem:
                nact[c] = nact.get(c, 0) + 1

        def rate(f: _Flow, c: str) -> float:
            return min(caps[c] / max(nact.get(c, 1), 1), f.demand[c])

        def flow_dt(f: Optional[_Flow]) -> float:
            if f is None or f.done():
                return 0.0
            drain = 0.0
            for c, v in f.rem.items():
                if v > _EPS:
                    drain = max(drain, v / rate(f, c))
            return f.latency + drain

        # time to next completion event
        dts = []
        for k in srv_op:
            dts.append(max(srv_left[k], flow_dt(srv_flow[k])))
        if exe_phase == "dist" and exe_flow:
            dts.append(flow_dt(exe_flow))
        elif exe_phase == "run":
            dts.append(max(exe_left, flow_dt(exe_flow)))
        if not dts:
            break
        dt = max(min(dts), 1e-9)

        # advance
        pre_active = bool(srv_op)
        exe_active = exe_phase != "idle"
        if pre_active and exe_active:
            overlap += dt
        elif pre_active:
            busy_hbm += dt
        elif exe_active:
            busy_exec += dt

        def advance(f: Optional[_Flow]) -> float:
            if f is None:
                return 0.0
            lat = min(f.latency, dt)
            f.latency -= lat
            eff = dt - lat
            served_total = 0.0
            if eff > 0:
                for c in list(f.rem):
                    v = f.rem[c]
                    if v <= _EPS:
                        continue
                    served = min(v, rate(f, c) * eff)
                    f.rem[c] = v - served
                    served_total += served
            return served_total

        for k in srv_op:
            srv_left[k] = max(0.0, srv_left[k] - dt)
            noc_bytes_served += advance(srv_flow[k])
        if exe_active:
            noc_bytes_served += advance(exe_flow)
        if exe_phase == "run":
            exe_left = max(0.0, exe_left - dt)
        t += dt

        # completions
        finished = [k for k in srv_op
                    if srv_left[k] <= _EPS and (srv_flow[k] is None
                                                or srv_flow[k].done())]
        for k in finished:
            pre_done[srv_op[k]] = True
            del srv_op[k], srv_flow[k], srv_left[k]
        if finished:
            start_next_preload()
        if exe_phase == "dist" and exe_flow and exe_flow.done():
            _enter_run()
        elif exe_phase == "run" and exe_left <= _EPS and (
                exe_flow is None or exe_flow.done()):
            exe_done[cur] = t
            space_used = max(0.0, space_used - exec_space(cur))
            exe_phase, exe_flow = "idle", None
            cur += 1
            start_next_preload()
            start_exec()

    total = t
    flops = sum(op.flops for op in graph.ops)
    hbm_bytes = sum((dec[j].preload_plan.hbm_bytes
                     if dec[j].preload_plan else 0) for j in range(n))
    util = Utilization(
        hbm=min(hbm_bytes / (hbm_bw * total), 1.0) if (hbm_bw and total)
        else 0.0,
        interconnect=min(noc_bytes_served / (cap_total * total), 1.0)
        if total else 0.0,
        flops=min(flops / (chip.total_flops * total), 1.0) if total else 0.0,
        achieved_tflops=flops / total / 1e12 if total else 0.0,
    )
    idle = max(0.0, total - busy_hbm - busy_exec - overlap)
    breakdown = Breakdown(preload_only=busy_hbm, execute_only=busy_exec,
                          overlapped=overlap, interconnect_stall=idle)
    return SimResult(total, breakdown, util, exe_done)


# ---------------------------------------------------------------------------
# pipeline-parallel pod simulation (DESIGN.md §7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineSimResult:
    total_time: float              # last microbatch leaves the last stage
    interval: float                # steady per-microbatch completion period
    fill_time: float               # first microbatch end-to-end
    stage_intervals: list          # per-stage steady interval (event-sim)
    microbatch_end: list           # per-microbatch completion times


def _tile_plan(plan: ExecutionPlan, copies: int) -> ExecutionPlan:
    """Concatenate ``copies`` repetitions of a stage plan into one plan:
    running it through the event simulator models a stage serving
    back-to-back microbatches, where copy ``c+1``'s preloads overlap copy
    ``c``'s execution under the same per-link-class contention the
    single-pass simulation uses."""
    g = plan.graph
    n = len(g.ops)
    ops = []
    for c in range(copies):
        for op in g.ops:
            if op.preload_dep >= 0:
                op = dataclasses.replace(op, preload_dep=op.preload_dep
                                         + c * n)
            ops.append(op)
    graph = OpGraph(g.model, g.phase, tuple(ops), g.layer_span,
                    g.num_layers * copies)
    order = [c * n + j for c in range(copies) for j in plan.preload_order]
    decs = [dataclasses.replace(d, op_idx=c * n + d.op_idx)
            for c in range(copies) for d in plan.decisions]
    timing = [OpTiming() for _ in ops]
    return ExecutionPlan(graph, plan.chip_name, plan.design, decs, order,
                         timing, 0.0, Breakdown(), Utilization())


def simulate_pipeline(pplan: "PipelinePlan", chip: ChipConfig,
                      microbatches: Optional[int] = None
                      ) -> PipelineSimResult:
    """Event-simulate a :class:`PipelinePlan`: every stage runs its
    microbatch stream on a member chip (``chip_view()``) with the
    per-link-class contention machinery, and inter-stage activation
    transfers cross the inter-chip tier — serialized per boundary (each
    boundary is the sending chip's own gateway links on ``hier_pod``; a
    bisection share on flat pools), so a slow tier backs the pipeline up
    exactly like any other contended resource.

    Stage plans must be exact (non-extrapolated): truncate the model before
    planning when simulating deep stacks, as the DSE sweeps do.

    Hybrid stages (DESIGN.md §9): a stage with tensor-parallel ``width``
    re-prices its intra-stage collectives from the plan's ``(kind, bytes)``
    descriptors through ``chip.topo.collective_time`` — the simulator's own
    view of the link tiers, not the planner's number — and serializes them
    at the end of each microbatch's service (ring steps synchronize every
    member chip, so they cannot overlap the next microbatch's compute).  A
    stage with ``replicas`` copies round-robins its microbatch stream over
    that many servers.  Width-1, replica-1 stages are bit-identical to the
    pure-pipeline composition.
    """
    view = chip.chip_view()
    M = microbatches if microbatches is not None else pplan.microbatches
    M = max(M, 1)
    for st in pplan.stages:
        if st.plan.extrapolated_from_layers:
            raise ValueError(
                "simulate_pipeline needs exact stage plans; plan a layer "
                "truncation of the model for simulation (stage "
                f"{st.index} extrapolated from "
                f"{st.plan.extrapolated_from_layers} layers)")
    # a one-stage single-chip plan was compiled against the whole pod
    # (degenerate path); everything else against the member chip view
    one = len(pplan.stages) == 1 and pplan.stages[0].chips == 1
    member = chip if one else view.chip
    # replicated stages bunch completions within one round, so their steady
    # cadence only shows over a second decode round (gated on the real
    # token dependency: a group re-enters stage 0 after its previous round
    # left the last stage).  Pure-pipeline plans keep the one-round path.
    cycles = 2 if any(st.replicas > 1 for st in pplan.stages) else 1
    Mt = M * cycles
    # per-stage microbatch completion times under intra-chip contention
    ends = []
    for st in pplan.stages:
        n = len(st.plan.graph.ops)
        res = simulate(_tile_plan(st.plan, Mt), member)
        ends.append([res.op_exec_end[(c + 1) * n - 1] for c in range(Mt)])
    # intra-stage collective time per microbatch, re-priced by the pod topo
    colls = [sum(chip.topo.collective_time(kind, b, st.width)
                 for kind, b in st.collectives) if st.width > 1 else 0.0
             for st in pplan.stages]
    # compose stages: microbatch m enters stage s after its predecessor on
    # the same stage finishes and after its own activation arrives over the
    # boundary (sends on one boundary are serialized in microbatch order)
    S = len(pplan.stages)
    t = [[0.0] * Mt for _ in range(S)]
    for s in range(S):
        durs = [ends[s][0]] + [ends[s][c] - ends[s][c - 1]
                               for c in range(1, Mt)]
        r = max(pplan.stages[s].replicas, 1)
        free = [0.0] * r               # r data-parallel servers round-robin
        send_prev_end = 0.0
        for m in range(Mt):
            if s == 0:
                # round 2 of a group waits for its round-1 sampled token
                arrive = t[S - 1][m - M] if m >= M else 0.0
            else:
                start = max(t[s - 1][m], send_prev_end)
                send_prev_end = start + pplan.stages[s - 1].send_time
                arrive = send_prev_end
            prev = t[s][m - 1] if m else 0.0
            done = max(arrive, free[m % r]) + durs[m] + colls[s]
            # keep handoffs in microbatch order for the next boundary
            free[m % r] = t[s][m] = max(done, prev)
    out = t[S - 1]
    if cycles > 1:
        # steady per-microbatch cadence across the second round
        interval = (out[Mt - 1] - out[M - 1]) / M
        stage_ivals = [(e[Mt - 1] - e[M - 1]) / M for e in ends]
    else:
        interval = ((out[M - 1] - out[0]) / (M - 1)) if M > 1 else out[0]
        stage_ivals = [((e[M - 1] - e[0]) / (M - 1)) if M > 1 else e[0]
                       for e in ends]
    return PipelineSimResult(out[Mt - 1], interval, out[0], stage_ivals, out)


# ---------------------------------------------------------------------------
# KV offload traffic (serve-side spills, DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVTrafficResult:
    total_time: float        # completion of the last transfer
    busy: dict               # tier index -> busy seconds on its server
    finish: list             # per-event completion times, input order


def simulate_kv_traffic(chip: ChipConfig, events, *, src: int = 0,
                        dst: Optional[int] = None) -> KVTrafficResult:
    """Serve the batcher's KV spill/refill events on the per-tier serial
    resources this simulator already models (§4.5 rule 2: one transfer at
    a time per off-core tier).

    ``events`` is ``ContinuousBatcher.spill_events``-shaped: ``(kind,
    nbytes)`` or ``(kind, nbytes, at)`` with ``at`` the earliest start
    time.  Every transfer moves one slot's ring between tier ``src``
    (default: the cores' SRAM) and ``dst`` (default: the chip's backing
    tier) and holds each *off-core* endpoint's server for
    ``AnalyticCostModel.spill_time`` — the identical pricing vocabulary
    the planner's ``ServeConfig.slot_spill_s`` uses, so plan-vs-sim
    agreement is a consistency gate (CI ``kvoffload-smoke``), with the
    simulator adding only the serialization a shared tier imposes."""
    from repro.core.cost_model import AnalyticCostModel

    cm = AnalyticCostModel(chip)
    if dst is None:
        dst = chip.backing_tier
    free: dict = {}
    busy: dict = {}
    finish = []
    for ev in events:
        kind, nbytes = ev[0], ev[1]
        at = float(ev[2]) if len(ev) > 2 else 0.0
        svc = cm.spill_time(nbytes, src, dst)
        start = max([at] + [free.get(t, 0.0) for t in (src, dst) if t > 0])
        end = start + svc
        for t in (src, dst):
            if t > 0:
                free[t] = end
                busy[t] = busy.get(t, 0.0) + svc
        finish.append(end)
    return KVTrafficResult(total_time=max(finish, default=0.0), busy=busy,
                           finish=finish)


def simulate_fleet_traffic(fleet, events) -> KVTrafficResult:
    """Re-serve a fleet router's KV migrations (DESIGN.md §12) on serial
    servers, one tier further up than :func:`simulate_kv_traffic`.

    ``events`` is ``FleetRouter.migration_events``-shaped: ``(nbytes, at,
    src_pod, dst_pod)`` per migration.  Each migration is three chained
    legs — offload on the source pod's backing tier, the inter-pod wire,
    refill on the destination pod's backing tier — priced exactly as the
    plan's ``FleetSpec.migration_time`` (same ``spill_time`` +
    ``transfer_time`` vocabulary), with the simulator adding only the
    serialization shared resources impose: one transfer at a time per pod
    backing tier (§4.5 rule 2 again) and one at a time on the fleet link.
    ``busy`` keys are ``("pod", i)`` for pod ``i``'s backing tier and
    ``"fleet"`` for the inter-pod link."""
    from repro.core.cost_model import AnalyticCostModel

    cms = [AnalyticCostModel(p) for p in fleet.pods]
    free: dict = {}
    busy: dict = {}
    finish = []
    for nbytes, at, src, dst in events:
        off = cms[src].spill_time(nbytes, 0, fleet.pods[src].backing_tier)
        wire = fleet.transfer_time(nbytes)
        ref = cms[dst].spill_time(nbytes, 0, fleet.pods[dst].backing_tier)
        t0 = max(float(at), free.get(("pod", src), 0.0))
        t1 = max(t0 + off, free.get("fleet", 0.0))
        t2 = max(t1 + wire, free.get(("pod", dst), 0.0))
        end = t2 + ref
        free[("pod", src)] = t0 + off
        free["fleet"] = t1 + wire
        free[("pod", dst)] = end
        busy[("pod", src)] = busy.get(("pod", src), 0.0) + off
        busy["fleet"] = busy.get("fleet", 0.0) + wire
        busy[("pod", dst)] = busy.get(("pod", dst), 0.0) + ref
        finish.append(end)
    return KVTrafficResult(total_time=max(finish, default=0.0), busy=busy,
                           finish=finish)
