"""Pluggable interconnect topology models (paper §5 mapping strategies, §6.4).

The paper's second headline claim is that the ICCA simulator enables
"architecture design space exploration with different interconnect network
topologies".  This module makes topology a first-class axis: each
:class:`TopologyModel` owns

* **routing** — per-traffic-class hop weights (``preload`` delivery from the
  HBM controllers, ``dist`` peer fetches at the preload->execute transition,
  ``rot`` compute-shift rotation / ring-reduce traffic during execution),
  HBM-controller placement, and bisection capacity;
* **link classes** — the contended resource pools the event simulator
  processor-shares.  Flat topologies expose one ``intra`` class; the
  hierarchical multi-chip pod adds a distinct, slower ``inter`` tier so
  congestion on one tier stretches only the flows that cross it;
* **collective cost shapes** — the serial-time factors the analytic cost
  model applies to broadcast preload, rotation and distribution transfers,
  so ELK's plans (not just the simulator) react to topology.

``ChipConfig`` delegates its NoC vocabulary (``noc_capacity``,
``preload_hops``, ``dist_hops``, ``preload_noc_bw``, ``noc_occupancy``) to
the model bound by :func:`build_topology`; ``signature()`` feeds the compile
pipeline's cache keys so curves/windows/plans miss when topology changes.

Numeric compatibility: ``all2all`` and ``mesh2d`` reproduce the pre-refactor
scalar hop-weight constants exactly (capacity ``N*link`` / ``4N*link``,
preload hops ``1`` / ``(r+c)/4``, dist hops ``1`` / ``2``, unit serial-time
factors), so existing plans are bit-identical.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import cached_property, lru_cache
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid a circular import; chip/config.py imports us
    from repro.chip.config import ChipConfig

TRAFFIC_CLASSES = ("preload", "dist", "rot")

# collective shapes the hybrid pod planner prices (DESIGN.md §9): ring
# algorithms over the member chips of a pod, on the tier a chip-to-chip
# transfer crosses
COLLECTIVE_KINDS = ("all_reduce", "reduce_scatter", "all_gather",
                    "all_to_all")


@dataclasses.dataclass(frozen=True)
class LinkClass:
    """One contended interconnect tier (fluid capacity pool)."""
    name: str            # "intra" | "inter"
    capacity: float      # aggregate bytes/s of the tier
    hop_latency: float   # per-hop latency on this tier (s)


@dataclasses.dataclass(frozen=True)
class ChipView:
    """Projection of a pod topology onto one member chip (DESIGN.md §7).

    ``chip`` is a ``ChipConfig`` describing a single member chip — the pod's
    per-chip core pool, SRAM, and HBM share with ``num_chips=1``, so its own
    ``topo`` exposes only the intra-chip link classes.  ``inter_bw`` /
    ``inter_latency`` expose the inter-chip tier a stage-to-stage activation
    flow crosses: the bandwidth one chip-pair boundary can sustain (one
    chip's gateway links on ``hier_pod``; a bisection share on flat pools)
    and the per-transfer latency across the tier.
    """
    chip: "ChipConfig"
    num_chips: int
    inter_bw: float
    inter_latency: float
    #: tensor-parallel width of the stage this view serves.  The member chip
    #: itself is unchanged (per-chip HBM bandwidth and SRAM are physical);
    #: the byte division lives in the sharded graph the planner compiles
    #: against this view (``pipeline_pod.shard_graph``, DESIGN.md §9), and
    #: the intra-stage collective term is priced by ``collective_time``.
    width: int = 1


def near_square_grid(n: int) -> tuple[int, int]:
    """Near-square factorization of ``n`` cores into a 2D grid.

    Prime (and near-prime) ``n`` degenerates to a pencil — ``(1, n)`` or
    e.g. ``(2, 23)`` — silently inflating ``preload_hops``; whenever the
    best factorization is worse than 2:1, pad to the nearest count whose
    grid is at most 2:1 instead (idle grid slots, honest hop counts) and
    warn.
    """
    def factor(m: int) -> tuple[int, int]:
        r = int(m ** 0.5)
        while m % r:
            r -= 1
        return (r, m // r)

    r, c = factor(n)
    if c > 2 * r:
        m = n + 1
        while True:
            r, c = factor(m)
            if r > 1 and c <= 2 * r:
                break
            m += 1
        warnings.warn(
            f"near-square grid: {n} cores has no near-square "
            f"factorization; padding to a {r}x{c} grid ({r * c - n} idle "
            "slots)", stacklevel=2)
    return (r, c)


class TopologyModel:
    """Base interconnect model bound to one chip's shape.

    Subclasses fill in, from the chip fields captured here:

    * ``classes`` — tuple of :class:`LinkClass` pools;
    * ``weights`` — ``{traffic kind: {class name: hop weight}}``; a flow of
      ``B`` bytes of kind ``k`` puts ``B * weights[k][c]`` byte-hops on
      class ``c``;
    * ``preload_hops`` / ``dist_hops`` / ``rot_hops`` — scalar summaries
      (mean hop counts) used for reporting and back-compat;
    * ``dist_time_factor`` / ``rot_time_factor`` — serial per-core transfer
      time multipliers on ``volume / link_bw`` (slow-tier crossings);
    * ``dist_latency_hops`` / ``rot_latency_hops`` — hop counts charged as
      per-transfer latency by the analytic cost model and the simulator.
    """

    kind = "base"

    def __init__(self, chip: "ChipConfig"):
        self._chip = chip
        self.num_cores = chip.num_cores
        self.num_chips = max(chip.num_chips, 1)
        self.cores_per_chip = chip.cores_per_chip
        self.link_bw = chip.link_bw
        self.link_latency = chip.link_latency
        self.hbm_controllers = chip.hbm_controllers
        self.classes: tuple[LinkClass, ...] = ()
        self.weights: dict[str, dict[str, float]] = {}
        self.preload_hops = 1.0
        self.dist_hops = 1.0
        self.rot_hops = 1.0
        self.dist_time_factor = 1.0
        self.rot_time_factor = 1.0
        self.dist_latency_hops = 1.0
        self.rot_latency_hops = 1.0
        self.bisection_bw = 0.0

    # -- interface the compiler core / simulator consume ---------------------
    # (total_capacity / preload_delivery_bw / signature sit on scheduler and
    # allocator hot paths — computed once per model, then plain lookups)
    def flow_weights(self, kind: str) -> dict[str, float]:
        return self.weights[kind]

    @cached_property
    def total_capacity(self) -> float:
        return sum(lc.capacity for lc in self.classes)

    @cached_property
    def preload_delivery_bw(self) -> float:
        """Effective HBM-controller->cores delivery bandwidth: the bottleneck
        link class's capacity diluted by the preload hop weight it carries."""
        return min(lc.capacity / self.weights["preload"][lc.name]
                   for lc in self.classes
                   if self.weights["preload"].get(lc.name, 0.0) > 0.0)

    @cached_property
    def preload_latency(self) -> float:
        """Pipeline-fill latency of one broadcast-preload delivery (s)."""
        return self.preload_hops * self.classes[0].hop_latency

    @cached_property
    def dist_latency(self) -> float:
        """Per-transfer latency of one data-distribution fetch (s), summed
        over the hop latencies of the link classes it crosses."""
        return self.dist_latency_hops * self.classes[0].hop_latency

    @cached_property
    def _occ_terms(self) -> tuple:
        # flattened (1/capacity, rot_w, preload_w, dist_w) per class: the
        # allocator calls occupancy() per candidate window, so keep it a
        # few multiplies rather than dict lookups
        return tuple((1.0 / lc.capacity,
                      self.weights["rot"].get(lc.name, 0.0),
                      self.weights["preload"].get(lc.name, 0.0),
                      self.weights["dist"].get(lc.name, 0.0))
                     for lc in self.classes)

    def occupancy(self, exec_bytes: float, preload_bytes: float,
                  dist_bytes: float = 0.0) -> float:
        """Seconds of capacity consumed by a traffic mix: the bottleneck
        tier's weighted byte-hops over its capacity (flat topologies reduce
        to the single-pool ``weighted / noc_capacity`` of the seed model)."""
        terms = self._occ_terms
        inv, rw, pw, dw = terms[0]
        t = (exec_bytes * rw + preload_bytes * pw + dist_bytes * dw) * inv
        for inv, rw, pw, dw in terms[1:]:
            t = max(t, (exec_bytes * rw + preload_bytes * pw
                        + dist_bytes * dw) * inv)
        return t

    def chip_view(self, width: int = 1) -> ChipView:
        """Project the pod onto one member chip (DESIGN.md §7, §9).

        The member ``ChipConfig`` keeps this chip's share of every per-chip
        resource (cores, SRAM, HBM bandwidth and controllers) with
        ``num_chips=1``, so planning against it sees only intra-chip link
        classes.  The inter-chip tier is exposed separately as the bandwidth
        one stage-to-stage boundary sustains.  Flat pools (no distinct inter
        tier) attribute a bisection share per chip-pair boundary; a
        single-chip config projects to itself with the full on-chip
        bisection as the (never-crossed) boundary bandwidth.

        ``width > 1`` marks the view as serving one shard of a stage that
        spans ``width`` member chips: the per-chip resources are unchanged,
        the weight/KV byte division is applied by the sharded stage graph
        compiled against this view, and the intra-stage collective term is
        priced separately via :meth:`collective_time`.
        """
        self._check_width(width)
        chip = self._chip
        n = self.num_chips
        if n <= 1:
            return ChipView(chip, 1, self.bisection_bw, chip.link_latency)
        member = chip.scaled(
            name=f"{chip.name}/chip",
            num_cores=self.cores_per_chip, num_chips=1,
            hbm_bw=chip.hbm_bw / n,
            hbm_controllers=max(chip.hbm_controllers // n, 1),
            mem_divide=n)
        return ChipView(member, n, self.bisection_bw / max(n - 1, 1),
                        2 * chip.link_latency, width)

    def _check_width(self, width: int) -> None:
        if not 1 <= width <= max(self.num_chips, 1):
            raise ValueError(
                f"width {width} out of range for a {self.num_chips}-chip "
                f"{self.kind} pod (need 1 <= width <= num_chips)")

    # -- collective cost API (hybrid pod planner, DESIGN.md §9) --------------
    def _collective_boundary(self, link_class: str | None) -> tuple:
        """(bandwidth, per-step latency) of one chip-pair boundary on the
        tier a ring collective's steps cross.  Matches ``chip_view()``'s
        inter-tier numbers exactly so the planner's send and collective
        terms price the same physical links."""
        names = [lc.name for lc in self.classes]
        if link_class is None:
            link_class = "inter" if "inter" in names else names[0]
        if link_class not in names:
            raise ValueError(
                f"unknown link class {link_class!r} on {self.kind}; "
                f"known: {names}")
        # flat pools: every tier is the on-chip pool; a chip-pair boundary
        # sustains a bisection share, two hop latencies per step
        return (self.bisection_bw / max(self.num_chips - 1, 1),
                2 * self.link_latency)

    def collective_time(self, kind: str, nbytes: float, width: int,
                        link_class: str | None = None) -> float:
        """Ring-algorithm time (s) of one collective among ``width`` member
        chips, each contributing/holding ``nbytes`` of payload.

        Shapes (``COLLECTIVE_KINDS``): reduce-scatter and all-gather each
        move ``(width-1)/width * nbytes`` through one chip-pair boundary in
        ``width-1`` latency-bearing steps; all-reduce composes the two
        (RS + AG, the standard ring decomposition); all-to-all keeps
        ``1/width`` of the payload local and rings the rest, which costs
        the same single pass.  Degenerate cases (``width <= 1`` or zero
        bytes) are free so pure-pipeline plans are untouched.
        """
        if kind not in COLLECTIVE_KINDS:
            raise ValueError(f"unknown collective kind {kind!r}; known: "
                             f"{COLLECTIVE_KINDS}")
        self._check_width(max(width, 1))
        if width <= 1 or nbytes <= 0:
            return 0.0
        bw, lat = self._collective_boundary(link_class)
        steps = width - 1
        single_pass = (nbytes * steps / width) / bw + steps * lat
        return 2.0 * single_pass if kind == "all_reduce" else single_pass

    def signature(self) -> tuple:
        """Hashable identity for compile-pipeline cache keys (memoized)."""
        sig = self.__dict__.get("_sig")
        if sig is None:
            sig = self.__dict__["_sig"] = self._signature()
        return sig

    def _signature(self) -> tuple:
        return (self.kind, self.num_cores, self.num_chips, self.link_bw,
                tuple((lc.name, lc.capacity) for lc in self.classes),
                tuple(sorted((k, tuple(sorted(w.items())))
                             for k, w in self.weights.items())),
                self.dist_time_factor, self.rot_time_factor,
                self.preload_hops)


class All2AllTopology(TopologyModel):
    """Every core drives one full-bandwidth link at a time (IPU exchange):
    capacity ``N * link_bw``, every transfer is one hop."""

    kind = "all2all"

    def __init__(self, chip):
        super().__init__(chip)
        cap = self.num_cores * self.link_bw
        self.classes = (LinkClass("intra", cap, self.link_latency),)
        self.weights = {"preload": {"intra": 1.0},
                        "dist": {"intra": 1.0},
                        "rot": {"intra": 1.0}}
        self.bisection_bw = cap / 2.0
        self.preload_hops = 1.0
        self.dist_hops = 1.0


class Mesh2DTopology(TopologyModel):
    """Per-chip 2D mesh, dimension-order routing (paper §6.1): each core
    talks to up to 4 neighbors simultaneously => capacity ``4N * link_bw``;
    a transfer consumes one link per hop.  Partition dims map to mesh dims,
    so rotations are neighbor hops (1) and distribution fetches within a
    group span ~2 hops; HBM controllers sit on the grid edges, so preload
    traffic crosses ``(rows+cols)/4`` links on average."""

    kind = "mesh2d"

    def __init__(self, chip):
        super().__init__(chip)
        r, c = chip.mesh_shape
        self.grid = (r, c)
        cap = 4 * self.num_cores * self.link_bw
        self.classes = (LinkClass("intra", cap, self.link_latency),)
        self.preload_hops = max((r + c) / 4.0, 1.0)
        self.dist_hops = 2.0
        self.weights = {"preload": {"intra": self.preload_hops},
                        "dist": {"intra": self.dist_hops},
                        "rot": {"intra": 1.0}}
        self.bisection_bw = min(r, c) * self.link_bw * self.num_chips

    def _signature(self) -> tuple:
        return super()._signature() + (self.grid,)


class Torus2DTopology(Mesh2DTopology):
    """Mesh2D with wraparound links: the same 4 links per core, but mean
    routing distances halve (preload crosses ``(r+c)/8``, distribution
    ~1.5 hops) and the bisection doubles.  Rotation stays a true ring of
    neighbor hops, so at equal ``link_bw`` torus rotation time is never
    worse than mesh."""

    kind = "torus2d"

    def __init__(self, chip):
        super().__init__(chip)
        r, c = self.grid
        self.preload_hops = max((r + c) / 8.0, 1.0)
        self.dist_hops = 1.5
        self.weights = {"preload": {"intra": self.preload_hops},
                        "dist": {"intra": self.dist_hops},
                        "rot": {"intra": 1.0}}
        self.bisection_bw = 2 * min(r, c) * self.link_bw * self.num_chips


class RingTopology(TopologyModel):
    """Per-chip bidirectional ring: two links per core => capacity
    ``2N * link_bw``.  HBM controllers are spaced evenly around the ring,
    so broadcast preload travels ``cores_per_chip / (4 * controllers)``
    hops on average — rings scale poorly for delivery, which is the point
    of including one in the DSE sweep.  Rotation is the natural fit (ring
    neighbors), distribution crosses ~4 hops."""

    kind = "ring"

    def __init__(self, chip):
        super().__init__(chip)
        cap = 2 * self.num_cores * self.link_bw
        self.classes = (LinkClass("intra", cap, self.link_latency),)
        ctrl_per_chip = max(self.hbm_controllers // self.num_chips, 1)
        self.preload_hops = max(
            self.cores_per_chip / (4.0 * ctrl_per_chip), 1.0)
        self.dist_hops = 4.0
        self.dist_time_factor = 2.0
        self.dist_latency_hops = 2.0
        self.weights = {"preload": {"intra": self.preload_hops},
                        "dist": {"intra": self.dist_hops},
                        "rot": {"intra": 1.0}}
        self.bisection_bw = 2 * self.link_bw * self.num_chips


class HierPodTopology(TopologyModel):
    """Hierarchical multi-chip pod: each chip is all2all internally
    (``intra`` class, per-chip HBM controllers => preload never leaves the
    chip) while chips connect through a distinct, slower ``inter`` tier of
    ``inter_links_per_chip`` gateway links per chip at
    ``inter_bw_ratio * link_bw`` each.  Distribution fetches peers
    uniformly, so ``(num_chips-1)/num_chips`` of that traffic crosses the
    thin tier; a rotation ring laid out chip-contiguously crosses it only
    ``num_chips / num_cores`` of the time.  Serial transfer times stretch
    by the harmonic blend of the two tiers' speeds."""

    kind = "hier_pod"

    def __init__(self, chip):
        super().__init__(chip)
        ratio = chip.inter_bw_ratio
        intra_cap = self.num_cores * self.link_bw
        inter_cap = (self.num_chips * chip.inter_links_per_chip
                     * self.link_bw * ratio)
        self.classes = (LinkClass("intra", intra_cap, self.link_latency),
                        LinkClass("inter", inter_cap, 4 * self.link_latency))
        fi = (self.num_chips - 1) / self.num_chips if self.num_chips > 1 \
            else 0.0
        fr = min(1.0, self.num_chips / self.num_cores) if self.num_chips > 1 \
            else 0.0
        self.frac_dist_inter = fi
        self.frac_rot_inter = fr
        self.preload_hops = 1.0
        self.dist_hops = 1.0 + fi
        self.dist_time_factor = (1.0 - fi) + fi / ratio
        self.rot_time_factor = (1.0 - fr) + fr / ratio
        self.weights = {"preload": {"intra": 1.0},
                        "dist": {"intra": 1.0, "inter": fi},
                        "rot": {"intra": 1.0, "inter": fr}}
        self.bisection_bw = inter_cap / 2.0 if self.num_chips > 1 \
            else intra_cap / 2.0

    @cached_property
    def dist_latency(self) -> float:
        # one intra hop to the gateway + one (slower) inter-chip hop; a
        # single-chip pod never crosses the gateway, so it must match the
        # corresponding flat all2all chip exactly (degenerate equivalence,
        # tests/test_pipeline_pod.py)
        by = {lc.name: lc.hop_latency for lc in self.classes}
        if self.num_chips <= 1:
            return by["intra"]
        return by["intra"] + by["inter"]

    def chip_view(self, width: int = 1) -> ChipView:
        self._check_width(width)
        chip = self._chip
        n = self.num_chips
        if n <= 1:
            by = {lc.name: lc.hop_latency for lc in self.classes}
            return ChipView(chip, 1, self.bisection_bw, by["intra"])
        member = chip.scaled(
            name=f"{chip.name}/chip",
            num_cores=self.cores_per_chip, num_chips=1,
            hbm_bw=chip.hbm_bw / n,
            hbm_controllers=max(chip.hbm_controllers // n, 1),
            mem_divide=n)
        # one boundary = the sending chip's gateway links; hops: one intra
        # hop to the gateway + one inter-chip hop
        by = {lc.name: lc.hop_latency for lc in self.classes}
        return ChipView(member, n,
                        chip.inter_links_per_chip * chip.link_bw
                        * chip.inter_bw_ratio,
                        by["intra"] + by["inter"], width)

    def _collective_boundary(self, link_class: str | None) -> tuple:
        # cross-chip collectives ride the gateway tier: one boundary = the
        # sending chip's gateway links, per-step latency = intra hop to the
        # gateway + one (slower) inter-chip hop — the same numbers
        # chip_view() exposes for stage-to-stage sends
        names = [lc.name for lc in self.classes]
        if link_class is None:
            link_class = "inter" if self.num_chips > 1 else "intra"
        if link_class not in names:
            raise ValueError(
                f"unknown link class {link_class!r} on {self.kind}; "
                f"known: {names}")
        if link_class == "inter" and self.num_chips > 1:
            chip = self._chip
            by = {lc.name: lc.hop_latency for lc in self.classes}
            return (chip.inter_links_per_chip * chip.link_bw
                    * chip.inter_bw_ratio,
                    by["intra"] + by["inter"])
        return super()._collective_boundary("intra")

    def _signature(self) -> tuple:
        return super()._signature() + (self.frac_dist_inter,
                                       self.frac_rot_inter)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A fleet of pods behind one router (DESIGN.md §12): per-pod
    ``ChipConfig``s plus the inter-pod tier a KV migration crosses.

    This extends the tiering pattern one level up, the way ``hier_pod``
    already stacks a slower ``inter`` gateway tier on top of each chip's
    ``intra`` links: the fleet adds a ``pod`` link class — datacenter
    fabric between pods — that is again thinner (``inter_bw_ratio`` of the
    slowest pod's bisection when not given explicitly) and again
    higher-latency (two pod-gateway hops on top of a fabric hop).  A
    prefill->decode migration's wire leg is priced here; the endpoint
    offload/refill legs are priced by each pod's own
    ``AnalyticCostModel.spill_time``, and ``chip.simulator.
    simulate_fleet_traffic`` re-serves all three legs on serial servers.
    """
    pods: tuple["ChipConfig", ...]
    inter_pod_bw: float = 0.0        # bytes/s one pod-pair boundary
    #                                  sustains (0 = derive from the pods)
    inter_pod_latency: float = 0.0   # per-transfer latency across the
    #                                  fleet tier (0 = derive)
    inter_bw_ratio: float = 0.25     # dilution vs the slowest pod's
    #                                  bisection when deriving

    def __post_init__(self):
        if not self.pods:
            raise ValueError("FleetSpec needs at least one pod")
        if self.inter_pod_bw <= 0:
            object.__setattr__(
                self, "inter_pod_bw",
                self.inter_bw_ratio
                * min(p.topo.bisection_bw for p in self.pods))
        if self.inter_pod_latency <= 0:
            # two pod-gateway crossings around one fabric hop, one tier
            # slower again than hier_pod's 4x-link-latency gateway
            object.__setattr__(
                self, "inter_pod_latency",
                8.0 * max(p.link_latency for p in self.pods))

    @property
    def num_pods(self) -> int:
        return len(self.pods)

    def link(self) -> LinkClass:
        """The fleet tier as a link class, same vocabulary as the intra
        and inter tiers below it."""
        return LinkClass("pod", self.inter_pod_bw, self.inter_pod_latency)

    def transfer_time(self, nbytes: float) -> float:
        """Wire time of one inter-pod transfer (the migration's middle
        leg): volume over the pod-pair boundary plus the fleet latency."""
        if nbytes <= 0:
            return 0.0
        return nbytes / self.inter_pod_bw + self.inter_pod_latency

    def migration_time(self, nbytes: float, src: int, dst: int) -> float:
        """Planned end-to-end cost of moving one KV ring from pod ``src``
        to pod ``dst``: offload off src's cores, the inter-pod wire, and
        the refill onto dst's cores — three serial legs, each priced by
        the tier it crosses."""
        from repro.core.cost_model import AnalyticCostModel

        a, b = self.pods[src], self.pods[dst]
        return (AnalyticCostModel(a).spill_time(nbytes, 0, a.backing_tier)
                + self.transfer_time(nbytes)
                + AnalyticCostModel(b).spill_time(nbytes, 0, b.backing_tier))

    def signature(self) -> tuple:
        """Hashable identity, fleet tier included — the same role
        ``TopologyModel.signature()`` plays in plan cache keys."""
        return (("fleet", self.inter_pod_bw, self.inter_pod_latency)
                + tuple(p.topo.signature() for p in self.pods))


def fleet_spec(pod: "ChipConfig", num_pods: int, *,
               inter_pod_bw: float = 0.0,
               inter_pod_latency: float = 0.0) -> FleetSpec:
    """Homogeneous fleet: ``num_pods`` copies of one pod config."""
    if num_pods < 1:
        raise ValueError(f"num_pods must be >= 1, got {num_pods}")
    return FleetSpec(pods=(pod,) * num_pods, inter_pod_bw=inter_pod_bw,
                     inter_pod_latency=inter_pod_latency)


TOPOLOGIES: dict[str, type[TopologyModel]] = {
    cls.kind: cls for cls in (All2AllTopology, Mesh2DTopology,
                              Torus2DTopology, RingTopology,
                              HierPodTopology)
}


@lru_cache(maxsize=256)
def _build(chip: "ChipConfig") -> TopologyModel:
    try:
        cls = TOPOLOGIES[chip.topology]
    except KeyError:
        raise KeyError(f"unknown topology {chip.topology!r}; known: "
                       f"{sorted(TOPOLOGIES)}") from None
    return cls(chip)


def build_topology(chip: "ChipConfig") -> TopologyModel:
    """The (memoized) TopologyModel bound to a chip's shape."""
    return _build(chip)
