"""Architecture registry.

``get_config(arch_id)`` returns the full assigned config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small layers/width/experts/vocab, as required by the assignment).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen1_5_32b",
    "h2o_danube_1_8b",
    "qwen3_14b",
    "gemma_7b",
    "internvl2_1b",
    "llama4_maverick_400b_a17b",
    "kimi_k2_1t_a32b",
    "rwkv6_7b",
    "whisper_tiny",
    "hymba_1_5b",
]

# canonical dashed ids accepted on the CLI
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "qwen1.5-32b": "qwen1_5_32b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "hymba-1.5b": "hymba_1_5b",
})

# The paper's own evaluation models (Table 2) — used by the faithful
# ELK-core benchmarks (benchmarks/fig17..24).
PAPER_MODEL_IDS = ["llama2_13b", "gemma2_27b", "opt_30b", "llama2_70b", "dit_xl"]


def canonical(arch: str) -> str:
    a = arch.replace("-", "_").replace(".", "_")
    if arch in ALIASES:
        return ALIASES[arch]
    if a in ARCH_IDS or a in PAPER_MODEL_IDS:
        return a
    raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + PAPER_MODEL_IDS}")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.smoke_config()


def _shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Default family-preserving reduction for smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=max(2, min(4, cfg.num_heads or 2)),
        num_kv_heads=0,  # fixed below
        d_ff=128,
        vocab_size=256,
        head_dim=16 if cfg.head_dim else 0,
    )
    nh = overrides.get("num_heads", base["num_heads"])
    if cfg.num_heads:
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        base["num_kv_heads"] = max(1, nh // min(ratio, nh))
    else:
        base["num_heads"] = 0
        base["num_kv_heads"] = 0
    if cfg.moe_experts:
        base.update(moe_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                    moe_d_ff=64 if cfg.moe_d_ff else 0,
                    moe_shared_d_ff=64 if cfg.moe_shared_d_ff else 0,
                    moe_first_dense=min(cfg.moe_first_dense, 1))
    if cfg.sliding_window:
        base["sliding_window"] = 16
    if cfg.encoder_layers:
        base.update(encoder_layers=2, encoder_seq=8)
    if cfg.vision_patches:
        base["vision_patches"] = 4
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
