"""DiT-XL — paper Table 2 diffusion transformer (compute-intensive case).
Modeled as a bidirectional dense transformer over 256 latent patches."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dit-xl",
    family="dense",
    num_layers=28,
    d_model=1152,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4608,
    vocab_size=8,              # in/out channels; negligible embed
    gated_mlp=False,
    mlp_act="gelu",
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, gated_mlp=False)
