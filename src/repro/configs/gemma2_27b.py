"""Gemma2-27B — paper Table 2 evaluation model (GQA)."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    gated_mlp=True,
    mlp_act="gelu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
