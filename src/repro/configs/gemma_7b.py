"""gemma-7b — dense, GeGLU, head_dim=256, kv=16.  [arXiv:2403.08295; hf]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    gated_mlp=True,
    mlp_act="gelu",           # GeGLU
    tie_embeddings=True,
    scale_embed=True,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, head_dim=16)
