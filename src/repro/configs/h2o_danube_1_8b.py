"""h2o-danube-1.8b — dense, llama+mistral mix, GQA kv=8, sliding-window attn.
[arXiv:2401.16818; hf]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    gated_mlp=True,
    mlp_act="silu",
    sliding_window=4096,
    swa_layers="all",
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
