"""hymba-1.5b — hybrid-head: parallel attention + mamba heads in each layer,
ssm_state=16, SWA on most layers.  [arXiv:2411.13676; hf].  Meta-tokens and the
3-global-layer pattern are simplified to all-SWA + parallel SSM branch (noted
in DESIGN.md)."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    gated_mlp=True,
    mlp_act="silu",
    sliding_window=1024,
    swa_layers="all",
    ssm_state=16,
    hybrid_parallel_ssm=True,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, num_heads=4, num_kv_heads=2, head_dim=16,
                   ssm_state=4)
