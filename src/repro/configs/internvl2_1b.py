"""internvl2-1b — VLM: InternViT frontend (stub) + Qwen2-0.5B-style backbone,
GQA kv=2.  [arXiv:2404.16821; hf].  The assignment specifies the transformer
BACKBONE; the vision frontend is a stub supplying precomputed patch embeddings
via input_specs()."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    gated_mlp=True,
    mlp_act="silu",
    tie_embeddings=True,
    frontend="vision_stub",
    vision_patches=256,          # one 448x448 tile -> 256 visual tokens
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
