"""kimi-k2-1t-a32b — trillion-param MoE: 384 experts top-8 + shared expert,
d_ff(expert)=2048, 64 q-heads GQA kv=8, dense first layer (DeepSeek-V3 style).
[arXiv:2501.kimi2 paper-table; unverified]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=18432,                # dense-layer / shared reference hidden
    vocab_size=163840,
    head_dim=112,              # 7168/64
    gated_mlp=True,
    mlp_act="silu",
    moe_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,             # per-expert hidden (assignment: d_ff=2048)
    moe_shared_d_ff=2048,
    moe_first_dense=1,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, moe_first_dense=1)
