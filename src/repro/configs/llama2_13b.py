"""Llama2-13B — paper Table 2 evaluation model (MHA)."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=13824,
    vocab_size=32000,
    gated_mlp=True,
    mlp_act="silu",
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
