"""Llama2-70B — paper Table 2 evaluation model (GQA kv=8)."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    gated_mlp=True,
    mlp_act="silu",
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
