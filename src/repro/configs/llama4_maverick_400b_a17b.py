"""llama4-maverick-400b-a17b — MoE 128 experts top-1 + shared expert, GQA kv=8,
early fusion (frontend not assigned -> text only).
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,                 # per-expert hidden
    vocab_size=202048,
    gated_mlp=True,
    mlp_act="silu",
    rope_theta=500_000.0,
    moe_experts=128,
    moe_top_k=1,
    moe_shared_d_ff=8192,      # maverick: shared expert alongside routed top-1
    moe_every=2,               # interleaved MoE (every other layer)
    moe_offset=1,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, moe_every=2, moe_offset=1)
