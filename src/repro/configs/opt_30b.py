"""OPT-30B — paper Table 2 evaluation model (MHA, non-gated GELU MLP)."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="opt-30b",
    family="dense",
    num_layers=48,
    d_model=7168,
    num_heads=56,
    num_kv_heads=56,
    d_ff=28672,
    vocab_size=50272,
    qkv_bias=True,
    gated_mlp=False,
    mlp_act="relu",
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, gated_mlp=False)
