"""qwen1.5-32b — dense, QKV bias, GQA kv=40 (==heads, i.e. MHA-equal).
[hf:Qwen/Qwen1.5-0.5B family; hf]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    gated_mlp=True,
    mlp_act="silu",
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG)
