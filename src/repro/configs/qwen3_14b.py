"""qwen3-14b — dense, qk-norm, GQA kv=8.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    gated_mlp=True,
    mlp_act="silu",
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, qk_norm=True)
