"""rwkv6-7b (Finch) — attention-free, data-dependent decay WKV recurrence.
[arXiv:2404.05892; hf]"""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,              # wkv heads (d_model/64)
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv=True,
    gated_mlp=False,           # rwkv channel-mix is its own 2-matrix form
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, num_heads=4, num_kv_heads=4)
