"""whisper-tiny — encoder-decoder, conv audio frontend (stub), 4L d=384.
[arXiv:2212.04356; unverified].  Frontend is a stub: input_specs() provides
precomputed mel-frame embeddings for the encoder."""

from repro.configs import _shrink
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    gated_mlp=False,
    mlp_act="gelu",
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return _shrink(CONFIG, gated_mlp=False)
