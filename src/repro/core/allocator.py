"""Cost-aware on-chip memory allocation (paper §4.3).

Given the currently executing operator and the set of operators preloaded
(resident) during its execution, jointly pick:

* the execute-state plan of the current op (Tradeoff 1: space <-> time),
* the preload-state plan of each resident op (Tradeoffs 2+3: space <->
  data-distribution time / exec-time inter-core traffic),

such that everything fits in on-chip memory and total window time is
minimized.  Exactly the paper's iterative greedy: start every op at its
fastest (largest-space) Pareto plan; while over capacity, downgrade the op
whose next Pareto step has the best ratio ``delta = freed_space /
added_time``; stop when it fits (or report infeasible).

The window cost combines (1) execution time, (2) data-distribution times,
(3) interconnect contention (total traffic / aggregate bandwidth, §4.3), and
(4) SRAM access contention (folded into ExecPlan.time per footnote 2).

Incremental solving (DESIGN.md §2)
----------------------------------
The §4.2 backward induction allocates a *family* of windows per operator
whose resident set grows by one preload as the cumulative issue count ``c``
increases.  :class:`IncrementalWindow` replays the greedy exactly while
sharing work across the family: the greedy's pop sequence — each round
takes the best ``freed/added`` head among the items' Pareto step streams,
first item winning ties — restricted to any subset of items is unaffected
by the other items, so the pop sequence for ``items + x`` is the head-by-
head merge of the existing sequence with ``x``'s own step stream.
``add_item`` performs that merge; ``solve`` then just selects the shortest
trace prefix whose freed space fits the capacity, reproducing a cold
``allocate()`` bit-for-bit at a fraction of the work.

Tiered placement (DESIGN.md §10)
--------------------------------
With an N-tier ``ChipConfig.mem_tiers`` hierarchy each capacity-bounded
store runs its *own* instance of the same greedy: a :class:`WindowItem`
carries the tier its space is charged against, and ``IncrementalWindow``
keeps one independent trace per tier (the pop-sequence subset property
holds per store, so the warm-start/exact-incremental contract is
preserved tier by tier).  Which tier a layer block is *sourced from* is
decided up front by :func:`place_tiers`: a deterministic longest-first
greedy that assigns blocks to the tier minimizing the steady-state
bottleneck preload chain, never exceeding a staging tier's capacity and
never beating the chain balance (a block stays in the backing store when
promoting it would not shrink the bottleneck).
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Optional, Sequence

from repro.chip.config import ChipConfig
from repro.core.partition import ExecPlan


@dataclasses.dataclass(frozen=True)
class WindowItem:
    """One op's Pareto curve inside an allocation window."""
    op_idx: int
    role: str                       # "exec" | "preload"
    plans: Sequence                 # ExecPlan list or PreloadPlan list
    fixed: bool = False             # plan already bound by an earlier window
    fixed_choice: int = 0
    tier: int = 0                   # memory tier the plan's space lives in


@dataclasses.dataclass
class Allocation:
    feasible: bool
    choices: dict[int, int]              # op_idx -> plan index on its curve
    exec_time: float                     # current op execution (incl. rotation)
    dist_time: float                     # sum of resident ops' future dist time
    noc_time: float                      # window interconnect occupancy (s)
    space: int                           # total per-core bytes
    cost: float                          # scalar objective used by the search

    def exec_plan(self, item: WindowItem) -> ExecPlan:
        return item.plans[self.choices[item.op_idx]]


def _window_cost(chip: ChipConfig, items: Sequence[WindowItem],
                 choice: dict[int, int], extra_preload_noc: float = 0.0,
                 ) -> tuple[float, float, float, float]:
    """Returns (cost, exec_time, dist_time, noc_time).

    ``extra_preload_noc`` carries the HBM-controller->core delivery bytes of
    the preloads *issued during* this window (scheduler-provided).  Resident
    ops' delivery traffic was charged to the window that issued it — counting
    it again here would double-book the interconnect and wrongly punish deep
    preloads.  Residents contribute only their (future) data-distribution
    time, which the greedy descent trades against space.
    """
    exec_t = 0.0
    dist_t = 0.0
    exec_noc = 0.0
    for it in items:
        p = it.plans[choice[it.op_idx]]
        if it.role == "exec":
            exec_t += p.time
            exec_noc += p.noc_exec_bytes
        else:
            dist_t += p.dist_time
    noc_t = chip.noc_occupancy(exec_noc, extra_preload_noc)
    # contention: interconnect time beyond what hides under execution stalls
    # the window (paper Fig. 18's "interconnect" category).
    stall = max(0.0, noc_t - exec_t)
    cost = exec_t + dist_t + stall
    return cost, exec_t, dist_t, noc_t


class _TierGreedy:
    """The §4.3 greedy trace for the items charged against one store."""

    __slots__ = ("cap", "base_space", "slots", "_streams", "_next",
                 "_trace", "_cum", "_heap")

    def __init__(self, cap: int):
        self.cap = cap
        self.base_space = 0          # all items at their starting choice
        self.slots: list[int] = []       # local slot -> global slot index
        self._streams: list[list] = []   # per slot: [(delta, freed), ...]
        self._next: list[int] = []       # per slot: first step not in trace
        self._trace: list[tuple] = []    # (delta, slot, freed) in pop order
        self._cum: list[float] = []      # prefix sums of freed space
        self._heap: list[tuple] = []     # (-delta, slot): heads beyond trace

    def add(self, item: WindowItem, global_slot: int) -> None:
        slot = len(self.slots)
        self.slots.append(global_slot)
        start = item.fixed_choice if item.fixed else 0
        self.base_space += item.plans[start].space
        steps: list[tuple] = []
        if not item.fixed:
            plans = item.plans
            j = start
            while j + 1 < len(plans):
                cur, nxt = plans[j], plans[j + 1]
                freed = cur.space - nxt.space
                if freed <= 0:
                    # the cold greedy never advances past a non-freeing step
                    break
                if item.role == "exec":
                    added = nxt.time - cur.time
                else:
                    added = nxt.dist_time - cur.dist_time
                steps.append((freed / max(added, 1e-12), freed))
                j += 1
        self._streams.append(steps)
        if not steps:
            self._next.append(0)
            return
        k = 0
        if self._trace:
            # head-by-head merge; existing (lower-slot) entries win ties
            merged: list[tuple] = []
            for e in self._trace:
                while k < len(steps) and steps[k][0] > e[0]:
                    merged.append((steps[k][0], slot, steps[k][1]))
                    k += 1
                merged.append(e)
            if k:
                self._trace = merged
                cum, run = [], 0.0
                for _, _, freed in merged:
                    run += freed
                    cum.append(run)
                self._cum = cum
        self._next.append(k)
        if k < len(steps):
            heapq.heappush(self._heap, (-steps[k][0], slot))

    def _extend(self) -> bool:
        """Materialize the next greedy pop into the trace."""
        if not self._heap:
            return False
        _, slot = heapq.heappop(self._heap)
        k = self._next[slot]
        delta, freed = self._streams[slot][k]
        self._trace.append((delta, slot, freed))
        self._cum.append((self._cum[-1] if self._cum else 0.0) + freed)
        self._next[slot] = k + 1
        if k + 1 < len(self._streams[slot]):
            nd, _ = self._streams[slot][k + 1]
            heapq.heappush(self._heap, (-nd, slot))
        return True

    def solve(self, counts: list[int]) -> bool:
        """Run this store's greedy to its fitting prefix; scatter per-item
        downgrade counts into the *global* ``counts`` array."""
        over = self.base_space - self.cap
        p = 0
        feasible = True
        if over > 0:
            while not self._cum or self._cum[-1] < over:
                if not self._extend():
                    feasible = False
                    break
            # cum is strictly increasing (every step frees space): the
            # shortest fitting prefix ends at the first entry >= over
            p = (bisect.bisect_left(self._cum, over) + 1 if feasible
                 else len(self._trace))
        for _, slot, _ in self._trace[:p]:
            counts[self.slots[slot]] += 1
        return feasible


class IncrementalWindow:
    """Exact incremental replay of the §4.3 greedy for a growing window.

    One independent :class:`_TierGreedy` per memory tier touched by the
    items (`WindowItem.tier`); the single-store behaviour — every item at
    tier 0 — is bit-identical to the pre-tier implementation.
    """

    def __init__(self, chip: ChipConfig, capacity: Optional[int] = None):
        self.chip = chip
        self.cap = capacity if capacity is not None \
            else chip.usable_sram_per_core
        self.items: list[WindowItem] = []
        self._tiers: dict[int, _TierGreedy] = {}

    @property
    def base_space(self) -> int:
        return sum(t.base_space for t in self._tiers.values())

    def _tier_state(self, tier: int) -> _TierGreedy:
        st = self._tiers.get(tier)
        if st is None:
            cap = (self.cap if tier <= 0
                   else self.chip.tier_capacity_per_core(tier))
            st = self._tiers[tier] = _TierGreedy(cap)
        return st

    def add_item(self, item: WindowItem) -> None:
        slot = len(self.items)
        self.items.append(item)
        self._tier_state(item.tier).add(item, slot)

    def solve_core(self) -> tuple:
        """Greedy result sans interconnect surcharge, cacheable by window
        signature: (feasible, per-slot choices, space, exec_t, dist_t,
        exec_noc_bytes)."""
        counts = [0] * len(self.items)
        feasible = True
        for tier in sorted(self._tiers):
            feasible &= self._tiers[tier].solve(counts)
        choices = []
        space = 0
        exec_t = dist_t = exec_noc = 0.0
        for slot, it in enumerate(self.items):
            ch = (it.fixed_choice if it.fixed else 0) + counts[slot]
            choices.append(ch)
            plan = it.plans[ch]
            space += plan.space
            if it.role == "exec":
                exec_t += plan.time
                exec_noc += plan.noc_exec_bytes
            else:
                dist_t += plan.dist_time
        return (feasible, tuple(choices), space, exec_t, dist_t, exec_noc)

    def solve(self, extra_preload_noc: float = 0.0) -> Allocation:
        return core_to_allocation(self.chip, self.items, self.solve_core(),
                                  extra_preload_noc)


def core_to_allocation(chip: ChipConfig, items: Sequence[WindowItem],
                       core: tuple, extra_preload_noc: float = 0.0
                       ) -> Allocation:
    """Finish a (possibly cached) greedy core into a full Allocation by
    folding in this window's preload-delivery surcharge."""
    feasible, choices, space, exec_t, dist_t, exec_noc = core
    by_op = {it.op_idx: ch for it, ch in zip(items, choices)}
    if not feasible:
        return Allocation(False, by_op, math.inf, math.inf, math.inf,
                          space, math.inf)
    noc_t = chip.noc_occupancy(exec_noc, extra_preload_noc)
    stall = max(0.0, noc_t - exec_t)
    return Allocation(True, by_op, exec_t, dist_t, noc_t, space,
                      exec_t + dist_t + stall)


def allocate(chip: ChipConfig, items: Sequence[WindowItem],
             capacity: Optional[int] = None,
             extra_preload_noc: float = 0.0) -> Allocation:
    win = IncrementalWindow(chip, capacity)
    for it in items:
        win.add_item(it)
    return win.solve(extra_preload_noc)


# ---------------------------------------------------------------------------
# Cross-tier source placement (DESIGN.md §10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierPlacement:
    """Where each layer weight block is sourced from, per memory tier."""
    tier_of: tuple                 # per-op index into chip.mem_tiers
    chains: tuple                  # per-tier steady serial preload chain (s)
    staged_bytes: tuple            # bytes resident per tier (0: sram/backing)
    noc_chain: float               # shared delivery-NoC serial floor (s)
    fill_time: float               # one-time refill backing -> staged tiers

    @property
    def bottleneck(self) -> float:
        return max(max(self.chains, default=0.0), self.noc_chain)


def place_tiers(chip: ChipConfig, ops: Sequence, cost=None, *,
                floor: float = 0.0) -> TierPlacement:
    """Assign each op's weight block a source tier (§4.3 generalized to N
    stores).

    Preloads from one tier are served sequentially by its controllers
    (paper §4.5), so the steady-state cost of a placement is the *longest
    per-tier serial chain* — each block contributing
    ``max(tier_time, noc_delivery)`` exactly as the schedule finalization
    charges it — with the shared core-delivery NoC as a global floor no
    promotion can beat.  ``floor`` (typically the execution-time chain)
    joins that max: staging a block onto a slower tier lengthens its own
    preload latency, so the greedy only moves blocks while the backing
    chain genuinely binds the steady interval.  Blocks are placed
    longest-first (LPT); each goes to the tier that minimizes the
    resulting bottleneck, staging tiers competing only while they have
    capacity left.  Ties keep the block in the backing store, so two-tier
    chips reproduce the flat placement exactly and the result is never
    worse than all-backing.
    """
    if cost is None:
        from repro.core.cost_model import AnalyticCostModel
        cost = AnalyticCostModel(chip)
    tiers = chip.mem_tiers
    backing = chip.backing_tier
    staging = chip.staging_tiers
    n = len(ops)
    sizes = [int(getattr(op, "hbm_bytes", 0)) for op in ops]
    tier_of = [backing] * n
    chains = {k: 0.0 for k in (backing, *staging) if k > 0}
    pre_bw = chip.preload_noc_bw
    t_noc = [nbytes / pre_bw if pre_bw > 0 else 0.0 for nbytes in sizes]
    noc_chain = sum(t_noc)
    if staging and backing > 0:
        used = {k: 0 for k in staging}
        order = sorted((j for j in range(n) if sizes[j] > 0),
                       key=lambda j: (-sizes[j], j))
        for j in order:
            nbytes = sizes[j]
            best_k = backing
            best_val = max(floor, noc_chain, max(chains.values()),
                           chains[backing]
                           + max(cost.tier_time(nbytes, backing), t_noc[j]))
            for k in staging:
                if used[k] + nbytes > tiers[k].capacity:
                    continue
                val = max(floor, noc_chain, max(chains.values()),
                          chains[k] + max(cost.tier_time(nbytes, k), t_noc[j]))
                # strictly-better only: ties stay in the backing store (and
                # once the shared-NoC or execution floor dominates, nothing
                # is staged)
                if val < best_val * (1 - 1e-12):
                    best_k, best_val = k, val
            if best_k == backing:
                # Latency-free fallback: even when the bottleneck chain
                # cannot improve (execution-bound stage), moving a block to
                # a tier that serves it at least as fast still drains the
                # backing controller's queue sooner — the schedule's
                # preload stalls shrink and nothing can get worse, since
                # the block's own service time does not grow and the tier's
                # chain stays within the all-backing trajectory.
                svc_b = max(cost.tier_time(nbytes, backing), t_noc[j])
                best_svc = svc_b
                for k in staging:
                    if used[k] + nbytes > tiers[k].capacity:
                        continue
                    svc_k = max(cost.tier_time(nbytes, k), t_noc[j])
                    if (svc_k <= best_svc
                            and chains[k] + svc_k <= chains[backing] + svc_b):
                        best_k, best_svc = k, svc_k
            tier_of[j] = best_k
            chains[best_k] += max(cost.tier_time(nbytes, best_k), t_noc[j])
            if best_k != backing:
                used[best_k] += nbytes
    elif backing > 0:
        for j in range(n):
            if sizes[j] > 0:
                chains[backing] += max(cost.tier_time(sizes[j], backing),
                                       t_noc[j])
    staged = [0] * len(tiers)
    for j, k in enumerate(tier_of):
        if 0 < k < backing:
            staged[k] += sizes[j]
    fill = sum(cost.spill_time(staged[k], backing, k)
               for k in range(len(tiers)) if staged[k] > 0)
    chain_vec = [0.0] * len(tiers)
    for k, v in chains.items():
        chain_vec[k] = v
    return TierPlacement(tuple(tier_of), tuple(chain_vec),
                         tuple(staged), noc_chain, fill)
