"""Cost-aware on-chip memory allocation (paper §4.3).

Given the currently executing operator and the set of operators preloaded
(resident) during its execution, jointly pick:

* the execute-state plan of the current op (Tradeoff 1: space <-> time),
* the preload-state plan of each resident op (Tradeoffs 2+3: space <->
  data-distribution time / exec-time inter-core traffic),

such that everything fits in on-chip memory and total window time is
minimized.  Exactly the paper's iterative greedy: start every op at its
fastest (largest-space) Pareto plan; while over capacity, downgrade the op
whose next Pareto step has the best ratio ``delta = freed_space /
added_time``; stop when it fits (or report infeasible).

The window cost combines (1) execution time, (2) data-distribution times,
(3) interconnect contention (total traffic / aggregate bandwidth, §4.3), and
(4) SRAM access contention (folded into ExecPlan.time per footnote 2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.chip.config import ChipConfig
from repro.core.partition import ExecPlan, PreloadPlan


@dataclasses.dataclass(frozen=True)
class WindowItem:
    """One op's Pareto curve inside an allocation window."""
    op_idx: int
    role: str                       # "exec" | "preload"
    plans: Sequence                 # ExecPlan list or PreloadPlan list
    fixed: bool = False             # plan already bound by an earlier window
    fixed_choice: int = 0


@dataclasses.dataclass
class Allocation:
    feasible: bool
    choices: dict[int, int]              # op_idx -> plan index on its curve
    exec_time: float                     # current op execution (incl. rotation)
    dist_time: float                     # sum of resident ops' future dist time
    noc_time: float                      # window interconnect occupancy (s)
    space: int                           # total per-core bytes
    cost: float                          # scalar objective used by the search

    def exec_plan(self, item: WindowItem) -> ExecPlan:
        return item.plans[self.choices[item.op_idx]]


def _space_of(item: WindowItem, j: int) -> int:
    p = item.plans[j]
    return p.space


def _window_cost(chip: ChipConfig, items: Sequence[WindowItem],
                 choice: dict[int, int], extra_preload_noc: float = 0.0,
                 ) -> tuple[float, float, float, float]:
    """Returns (cost, exec_time, dist_time, noc_time).

    ``extra_preload_noc`` carries the HBM-controller->core delivery bytes of
    the preloads *issued during* this window (scheduler-provided).  Resident
    ops' delivery traffic was charged to the window that issued it — counting
    it again here would double-book the interconnect and wrongly punish deep
    preloads.  Residents contribute only their (future) data-distribution
    time, which the greedy descent trades against space.
    """
    exec_t = 0.0
    dist_t = 0.0
    exec_noc = 0.0
    for it in items:
        p = it.plans[choice[it.op_idx]]
        if it.role == "exec":
            exec_t += p.time
            exec_noc += p.noc_exec_bytes
        else:
            dist_t += p.dist_time
    noc_t = chip.noc_occupancy(exec_noc, extra_preload_noc)
    # contention: interconnect time beyond what hides under execution stalls
    # the window (paper Fig. 18's "interconnect" category).
    stall = max(0.0, noc_t - exec_t)
    cost = exec_t + dist_t + stall
    return cost, exec_t, dist_t, noc_t


def allocate(chip: ChipConfig, items: Sequence[WindowItem],
             capacity: Optional[int] = None,
             extra_preload_noc: float = 0.0) -> Allocation:
    cap = capacity if capacity is not None else chip.usable_sram_per_core
    choice = {it.op_idx: (it.fixed_choice if it.fixed else 0) for it in items}
    space = sum(_space_of(it, choice[it.op_idx]) for it in items)

    def steppable(it: WindowItem) -> bool:
        return (not it.fixed) and choice[it.op_idx] + 1 < len(it.plans)

    while space > cap:
        best = None
        for it in items:
            if not steppable(it):
                continue
            j = choice[it.op_idx]
            cur, nxt = it.plans[j], it.plans[j + 1]
            freed = cur.space - nxt.space
            if freed <= 0:
                continue
            if it.role == "exec":
                added = nxt.time - cur.time
            else:
                added = nxt.dist_time - cur.dist_time
            delta = freed / max(added, 1e-12)
            if best is None or delta > best[0]:
                best = (delta, it)
        if best is None:
            return Allocation(False, choice, math.inf, math.inf, math.inf,
                              space, math.inf)
        _, it = best
        old = _space_of(it, choice[it.op_idx])
        choice[it.op_idx] += 1
        space += _space_of(it, choice[it.op_idx]) - old

    cost, exec_t, dist_t, noc_t = _window_cost(chip, items, choice,
                                               extra_preload_noc)
    return Allocation(True, choice, exec_t, dist_t, noc_t, space, cost)
