"""The §6.1 ablation designs: Basic, Static, ELK-Dyn, ELK-Full, Ideal.

* ``Basic`` — existing-DL-compiler behaviour: maximize execution space, use
  whatever remains to preload *the next operator only*.
* ``Static`` — T10 [34] extended with HBM support, SambaNova-style multi-op
  preload into a statically reserved preload space; the best static split is
  chosen per model (paper: "the sizes will not change throughout the model
  execution"); preload-state plans are all-max or all-min footprint,
  whichever is faster end-to-end.
* ``ELK-Dyn`` — §4.2 scheduling + §4.3 allocation, no reordering.
* ``ELK-Full`` — everything incl. §4.4 preload order permutation.
* ``Ideal`` — the roofline: dedicated interconnects for preload and
  execution, full-size memory for every op, zero-latency data distribution.
"""

from __future__ import annotations

from typing import Optional

from repro.chip.config import ChipConfig
from repro.core.graph import OpGraph
from repro.core.pipeline import CompileContext
from repro.core.plan import (Breakdown, ExecutionPlan, OpDecision, OpTiming,
                             Utilization)
from repro.core.reorder import best_reordered_plan
from repro.core.scheduler import Scheduler

DESIGNS = ("Basic", "Static", "ELK-Dyn", "ELK-Full", "Ideal")


def build_plan(graph: OpGraph, chip: ChipConfig, design: str,
               max_orders: int = 24, ctx: Optional[CompileContext] = None,
               parallel: Optional[int] = None) -> ExecutionPlan:
    """Per-design schedule/finalize/select passes.  One ``ctx`` serves every
    Scheduler built here, so the §6.1 baseline sweeps re-enumerate nothing."""
    ctx = ctx or CompileContext(chip)
    if design == "Basic":
        sched = Scheduler(graph, chip, max_preload=1, exec_fastest=True,
                          ctx=ctx)
        return sched.schedule(design="Basic")
    if design == "Static":
        return _static_plan(graph, chip, ctx)
    if design == "ELK-Dyn":
        return _elk_dyn(graph, chip, ctx=ctx)
    if design == "ELK-Full":
        sched = Scheduler(graph, chip, ctx=ctx)
        best = best_reordered_plan(sched, graph, chip, max_orders=max_orders,
                                   parallel=parallel)
        dyn = _elk_dyn(graph, chip, design="ELK-Full", ctx=ctx)
        return dyn if dyn.total_time < best.total_time else best
    if design == "Ideal":
        return ideal_plan(graph, chip, ctx)
    raise KeyError(design)


def _elk_dyn(graph: OpGraph, chip: ChipConfig, design: str = "ELK-Dyn",
             ctx: Optional[CompileContext] = None) -> ExecutionPlan:
    """ELK's dynamic scheduling.  The exact §4.2/§4.3 search dominates any
    fixed execution-space split by construction; our greedy allocator is
    approximate, so the search space is explicitly widened with the capped
    variants (a fixed cap is one point of the paper's search space) and
    the best schedule wins."""
    ctx = ctx or CompileContext(chip)
    cap = chip.usable_sram_per_core
    best = Scheduler(graph, chip, ctx=ctx).schedule(design=design)
    for frac in (0.25, 0.5, 0.75):
        for pfrac in (None, 0.0, 1.0):
            s = Scheduler(graph, chip, exec_space_cap=int(cap * frac),
                          static_preload_frac=pfrac, ctx=ctx)
            p = s.schedule(design=design)
            if p.total_time < best.total_time:
                best = p
    return best


def _static_plan(graph: OpGraph, chip: ChipConfig,
                 ctx: Optional[CompileContext] = None) -> ExecutionPlan:
    ctx = ctx or CompileContext(chip)
    cap = chip.usable_sram_per_core
    best = None
    for frac in (0.25, 0.5, 0.75):
        for pfrac in (0.0, 1.0):
            sched = Scheduler(graph, chip,
                              exec_space_cap=int(cap * frac),
                              static_preload_frac=pfrac, ctx=ctx)
            plan = sched.schedule(design="Static")
            if best is None or plan.total_time < best.total_time:
                best = plan
    return best


def ideal_plan(graph: OpGraph, chip: ChipConfig,
               ctx: Optional[CompileContext] = None) -> ExecutionPlan:
    """Roofline (paper §6.1 'Ideal'): exec pipeline and preload pipeline each
    run at full speed on private resources; total = max of the two."""
    ctx = ctx or CompileContext(chip)
    cost = ctx.cost
    n = len(graph.ops)
    timing = [OpTiming() for _ in range(n)]
    decisions = []
    t_exec_sum = 0.0
    t_pre_sum = 0.0
    for i, op in enumerate(graph.ops):
        plans = ctx.curves.exec_plans(op)
        fastest = plans[0]
        t_exec_sum += fastest.time
        t_pre = cost.hbm_time(op.hbm_bytes) if op.hbm_bytes else 0.0
        t_pre_sum += t_pre
        timing[i].t_s_exe = t_exec_sum - fastest.time
        timing[i].t_e_exe = t_exec_sum
        timing[i].t_s_pre = t_pre_sum - t_pre
        timing[i].t_e_pre = t_pre_sum
        decisions.append(OpDecision(i, 0, fastest, None))
    total = max(t_exec_sum, t_pre_sum)
    flops = sum(op.flops for op in graph.ops)
    hbm_bytes = sum(op.hbm_bytes for op in graph.ops)
    util = Utilization(
        hbm=min(hbm_bytes / (chip.hbm_bw * total), 1.0) if chip.hbm_bw else 0.0,
        interconnect=0.0,
        flops=min(flops / (chip.total_flops * total), 1.0),
        achieved_tflops=flops / total / 1e12,
    )
    overlap = min(t_exec_sum, t_pre_sum)
    breakdown = Breakdown(
        preload_only=max(0.0, t_pre_sum - overlap),
        execute_only=max(0.0, t_exec_sum - overlap),
        overlapped=overlap,
        interconnect_stall=0.0)
    return ExecutionPlan(graph, chip.name, "Ideal", decisions,
                         list(range(n)), timing, total, breakdown, util)
