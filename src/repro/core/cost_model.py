"""Cost models (paper §4.3 "Cost model for execution time").

Two models with one interface:

* ``AnalyticCostModel`` — closed-form per-core tile time (compute roofline with
  an MXU/AMP small-tile efficiency term + SRAM feed bound + per-chunk issue
  overhead) and a per-link transfer model (volume/bw + hop latency).  This is
  the ground-truth used by the event simulator.
* ``LinearTreeCostModel`` — the paper fits linear-tree regressors [10] on tiles
  profiled on real IPU hardware.  No IPU exists in this container, so the tree
  is fitted on microbenchmarks of the *simulator's* analytic model (DESIGN.md
  §4 hardware-adaptation note); Figure-12-style accuracy is reproduced as
  tree-vs-analytic agreement in ``benchmarks/fig12_costmodel.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.chip.config import ChipConfig

# fraction of peak a dim contributes when smaller than full MXU/AMP alignment
_ALIGN = 32.0
_CHUNK_OVERHEAD = 1e-6       # per rotation-chunk issue overhead (s)
_VECTOR_OVERHEAD = 1e-6


def _mxu_eff(tile_dims: Sequence[float]) -> float:
    """Efficiency of the matrix pipeline for a per-core tile.

    Small dims under-fill the systolic/AMP pipeline; efficiency is the product
    of per-dim fill ratios, floored to avoid degenerate zero-cost division."""
    eff = 1.0
    for t in tile_dims:
        eff *= min(1.0, max(t, 1.0) / _ALIGN)
    return max(eff, 1.0 / 4096.0)


class AnalyticCostModel:
    """Closed-form tile execution + link transfer costs."""

    def __init__(self, chip: ChipConfig):
        self.chip = chip

    # -- per-core execution --------------------------------------------------
    def tile_time(self, kind: str, tile_dims: Sequence[int],
                  tile_flops: float, tile_bytes: int,
                  chunks: int = 1) -> float:
        c = self.chip
        if kind == "matmul":
            peak = c.core_flops * _mxu_eff(tile_dims)
            t_comp = tile_flops / peak
            over = _CHUNK_OVERHEAD * max(chunks, 1)
        else:
            t_comp = tile_flops / c.core_flops_vector
            over = _VECTOR_OVERHEAD
        t_mem = tile_bytes / c.sram_bw_per_core
        return max(t_comp, t_mem) + over

    # -- interconnect ---------------------------------------------------------
    def link_time(self, volume: int, hops: int = 1, rounds: int = 1) -> float:
        c = self.chip
        return volume / c.link_bw + hops * rounds * c.link_latency

    def rot_time(self, volume: int, rounds: int = 1) -> float:
        """Compute-shift rotation / ring-reduce transfer time for one core's
        exec-phase traffic.  Topology-aware: crossings of a slower link tier
        stretch the serial time by ``rot_time_factor`` (1.0 on flat
        topologies, reproducing the plain per-link model)."""
        if volume <= 0:
            return 0.0
        c = self.chip
        topo = c.topo
        return (volume * topo.rot_time_factor / c.link_bw
                + topo.rot_latency_hops * max(rounds, 1) * c.link_latency)

    def dist_time(self, volume: int) -> float:
        """Data-distribution (preload->execute state) fetch time for one
        core, with the topology's slow-tier blend and the per-hop latency
        of every link class the fetch crosses."""
        if volume <= 0:
            return 0.0
        c = self.chip
        topo = c.topo
        return volume * topo.dist_time_factor / c.link_bw + topo.dist_latency

    def hbm_time(self, volume: int) -> float:
        c = self.chip
        if c.hbm_bw <= 0:
            return 0.0
        return volume / c.hbm_bw + c.hbm_latency

    def tier_time(self, volume: int, tier: int) -> float:
        """Preload-source roofline for a block resident in memory tier
        ``tier`` (DESIGN.md §10): its aggregate bandwidth plus per-request
        latency.  Tier 0 is the cores' own SRAM — the block is already
        resident, so sourcing it is free — and the backing tier reproduces
        ``hbm_time`` exactly (same operands, same operation order)."""
        if tier <= 0:
            return 0.0
        tiers = self.chip.mem_tiers
        t = tiers[min(tier, len(tiers) - 1)]
        if t.bandwidth <= 0:
            return 0.0
        return volume / t.bandwidth + t.latency

    def spill_time(self, volume: int, src: int, dst: int) -> float:
        """One-time staging transfer between two tiers (spill on the way
        down, refill on the way up): the volume at the slower endpoint's
        bandwidth plus both per-request latencies."""
        if volume <= 0 or src == dst:
            return 0.0
        tiers = self.chip.mem_tiers
        a = tiers[min(max(src, 0), len(tiers) - 1)]
        b = tiers[min(max(dst, 0), len(tiers) - 1)]
        bws = [t.bandwidth for t in (a, b) if t.bandwidth > 0]
        if not bws:
            return 0.0
        return volume / min(bws) + a.latency + b.latency

    def collective_time(self, kind: str, nbytes: float, width: int,
                        link_class: str | None = None) -> float:
        """Ring-collective time among ``width`` chips of the pod this chip
        belongs to (hybrid pod planner, DESIGN.md §9)."""
        return self.chip.topo.collective_time(kind, nbytes, width, link_class)


# ---------------------------------------------------------------------------
# Linear-tree regressor (paper ref [10], re-implemented minimally)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Leaf:
    coef: np.ndarray
    intercept: float


@dataclasses.dataclass
class _Node:
    feature: int
    threshold: float
    left: "object"
    right: "object"


def _fit_linear(X: np.ndarray, y: np.ndarray) -> _Leaf:
    A = np.concatenate([X, np.ones((len(X), 1))], axis=1)
    sol, *_ = np.linalg.lstsq(A, y, rcond=None)
    return _Leaf(sol[:-1], float(sol[-1]))


def _leaf_sse(X: np.ndarray, y: np.ndarray) -> float:
    leaf = _fit_linear(X, y)
    pred = X @ leaf.coef + leaf.intercept
    return float(np.sum((pred - y) ** 2))


class LinearTreeCostModel:
    """Piecewise-linear regression tree: split greedily on the (feature,
    median-quantile threshold) minimizing children linear-fit SSE."""

    def __init__(self, max_depth: int = 3, min_samples: int = 16):
        self.max_depth = max_depth
        self.min_samples = min_samples
        self.root: _Node | _Leaf | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "LinearTreeCostModel":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self.root = self._build(X, y, 0)
        return self

    def _build(self, X, y, depth):
        if depth >= self.max_depth or len(y) < 2 * self.min_samples:
            return _fit_linear(X, y)
        base = _leaf_sse(X, y)
        best = None
        for f in range(X.shape[1]):
            for q in (0.25, 0.5, 0.75):
                thr = float(np.quantile(X[:, f], q))
                mask = X[:, f] <= thr
                if mask.sum() < self.min_samples or (~mask).sum() < self.min_samples:
                    continue
                sse = _leaf_sse(X[mask], y[mask]) + _leaf_sse(X[~mask], y[~mask])
                if best is None or sse < best[0]:
                    best = (sse, f, thr, mask)
        if best is None or best[0] >= base * 0.999:
            return _fit_linear(X, y)
        _, f, thr, mask = best
        return _Node(f, thr,
                     self._build(X[mask], y[mask], depth + 1),
                     self._build(X[~mask], y[~mask], depth + 1))

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(len(X))
        for i, row in enumerate(X):
            node = self.root
            while isinstance(node, _Node):
                node = node.left if row[node.feature] <= node.threshold else node.right
            out[i] = row @ node.coef + node.intercept
        return out


def fit_tile_cost_model(chip: ChipConfig, kind: str = "matmul",
                        n_samples: int = 512, seed: int = 0,
                        ) -> tuple[LinearTreeCostModel, np.ndarray, np.ndarray]:
    """Paper §4.3: 'randomly generate tiles with varied shapes, run each tile
    ... fit a linear tree model using the tile shapes as inputs and the
    profiled execution times as outputs.'  Profiling target here = the
    analytic simulator core model."""
    rng = np.random.default_rng(seed)
    analytic = AnalyticCostModel(chip)
    X, y = [], []
    for _ in range(n_samples):
        if kind == "matmul":
            m, n, k = (int(2 ** rng.uniform(0, 9)) for _ in range(3))
            flops = 2.0 * m * n * k
            bts = 2 * (m * k + k * n + m * n)
            t = analytic.tile_time("matmul", (m, n, k), flops, bts)
            X.append([m, n, k, flops, bts])
        else:
            n = int(2 ** rng.uniform(4, 18))
            flops = 8.0 * n
            bts = 2 * n
            t = analytic.tile_time("vector", (n,), flops, bts)
            X.append([n, 1, 1, flops, bts])
        y.append(t)
    X, y = np.asarray(X), np.asarray(y)
    return LinearTreeCostModel().fit(X, y), X, y


def fit_link_cost_model(chip: ChipConfig, n_samples: int = 256, seed: int = 1,
                        ) -> tuple[LinearTreeCostModel, np.ndarray, np.ndarray]:
    """Per-link transfer-time regressor (volume -> time), paper Fig. 12."""
    rng = np.random.default_rng(seed)
    analytic = AnalyticCostModel(chip)
    X = (2 ** rng.uniform(6, 24, size=n_samples)).astype(np.int64)
    y = np.array([analytic.link_time(int(v)) for v in X])
    Xf = np.stack([X, np.ones_like(X)], axis=1).astype(np.float64)
    return LinearTreeCostModel(max_depth=2).fit(Xf, y), Xf, y
