"""Top-level ELK compile driver.

``compile_model(cfg, chip, design, ...)`` builds the operator graph and runs
the chosen §6.1 design's scheduling pipeline, returning an ``ExecutionPlan``.
Since the pass-pipeline refactor (DESIGN.md §1) this module is the thin
public API over ``core.pipeline``: compiles run through a ``CompileContext``
(shared Pareto-curve and allocation-window caches) and finished plans land
in a process-level cache consumed by serving/integration/benchmarks.

Large models (thousands of ops) exploit identical-layer periodicity: the
schedule is computed for two truncations L1 < L2 of the layer stack and the
end-to-end time is extrapolated linearly in the layer count (the backward
induction reaches a steady state after a couple of layers; tests validate
the extrapolation against exact schedules on small models).  This mirrors
the paper's own use of layer identity in §4.4 and keeps compile times in the
"minutes" regime the paper reports (Fig. 16).
"""

from __future__ import annotations

from typing import Optional

from repro.chip.config import ChipConfig
from repro.core.graph import Phase
from repro.core.pipeline import CompileContext, compile_pipeline
from repro.core.plan import ExecutionPlan
from repro.models.config import ModelConfig


def compile_model(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                  seq: int, phase: Phase = "decode",
                  design: str = "ELK-Full", max_exact_ops: int = 400,
                  max_orders: int = 24,
                  ctx: Optional[CompileContext] = None,
                  cache: bool = True,
                  parallel: Optional[int] = None,
                  fusion: bool = False) -> ExecutionPlan:
    """``fusion=True`` enables the §8 inter-core fusion pass: the fused and
    unfused graphs compile against one context and the faster plan wins
    (``plan.fusion`` records whether the fused graph was selected)."""
    return compile_pipeline(cfg, chip, batch=batch, seq=seq, phase=phase,
                            design=design, max_exact_ops=max_exact_ops,
                            max_orders=max_orders, ctx=ctx, cache=cache,
                            parallel=parallel, fusion=fusion)


def compare_designs(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                    seq: int, phase: Phase = "decode",
                    designs=("Basic", "Static", "ELK-Dyn", "ELK-Full",
                             "Ideal"),
                    ctx: Optional[CompileContext] = None,
                    fusion: bool = False,
                    **kw) -> dict[str, ExecutionPlan]:
    """Compile every design against one shared ``CompileContext`` — curves
    and allocation windows are computed once and reused across designs.
    ``fusion`` applies the §8 pass to every design; check ``plan.fusion``
    per design to see where the fused graph actually won."""
    ctx = ctx or CompileContext(chip)
    return {d: compile_model(cfg, chip, batch=batch, seq=seq, phase=phase,
                             design=d, ctx=ctx, fusion=fusion, **kw)
            for d in designs}
