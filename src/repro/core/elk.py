"""Top-level ELK compile driver.

``compile_model(cfg, chip, design, ...)`` builds the operator graph and runs
the chosen §6.1 design's scheduling pipeline, returning an ``ExecutionPlan``.

Large models (thousands of ops) exploit identical-layer periodicity: the
schedule is computed for two truncations L1 < L2 of the layer stack and the
end-to-end time is extrapolated linearly in the layer count (the backward
induction reaches a steady state after a couple of layers; tests validate
the extrapolation against exact schedules on small models).  This mirrors
the paper's own use of layer identity in §4.4 and keeps compile times in the
"minutes" regime the paper reports (Fig. 16).
"""

from __future__ import annotations

import dataclasses

from repro.chip.config import ChipConfig
from repro.core.baselines import build_plan
from repro.core.graph import Phase, build_graph
from repro.core.plan import Breakdown, ExecutionPlan, Utilization
from repro.models.config import ModelConfig


def compile_model(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                  seq: int, phase: Phase = "decode",
                  design: str = "ELK-Full", max_exact_ops: int = 400,
                  max_orders: int = 24) -> ExecutionPlan:
    graph = build_graph(cfg, batch=batch, seq=seq, phase=phase)
    if len(graph.ops) <= max_exact_ops:
        return build_plan(graph, chip, design, max_orders=max_orders)
    plan = _extrapolated(cfg, chip, batch, seq, phase, design, max_orders)
    if design in ("ELK-Dyn", "ELK-Full"):
        # ELK's search space contains every static configuration; linear
        # layer-extrapolation is not monotonicity-preserving across designs,
        # so re-impose dominance at the extrapolated level.
        st = _extrapolated(cfg, chip, batch, seq, phase, "Static",
                           max_orders)
        if st.total_time < plan.total_time:
            plan = dataclasses.replace(st, design=design)
    return plan


def _layer_counts(cfg: ModelConfig) -> tuple[int, int]:
    period = max(cfg.moe_every, 1) if cfg.moe_experts else 1
    l1 = cfg.moe_first_dense + 3 * period
    l2 = l1 + 2 * period
    if l2 >= cfg.num_layers:
        return cfg.num_layers, cfg.num_layers
    return l1, l2


def _extrapolated(cfg, chip, batch, seq, phase, design, max_orders
                  ) -> ExecutionPlan:
    l1, l2 = _layer_counts(cfg)
    cfg1 = dataclasses.replace(cfg, num_layers=l1)
    cfg2 = dataclasses.replace(cfg, num_layers=l2)
    g_full = build_graph(cfg, batch=batch, seq=seq, phase=phase)
    p1 = build_plan(build_graph(cfg1, batch=batch, seq=seq, phase=phase),
                    chip, design, max_orders=max_orders)
    p2 = build_plan(build_graph(cfg2, batch=batch, seq=seq, phase=phase),
                    chip, design, max_orders=max_orders)
    if l1 == l2:
        return p2

    scale = (cfg.num_layers - l2) / (l2 - l1)

    def ext(a: float, b: float) -> float:
        return max(b + (b - a) * scale, 0.0)

    total = ext(p1.total_time, p2.total_time)
    breakdown = Breakdown(
        preload_only=ext(p1.breakdown.preload_only, p2.breakdown.preload_only),
        execute_only=ext(p1.breakdown.execute_only, p2.breakdown.execute_only),
        overlapped=ext(p1.breakdown.overlapped, p2.breakdown.overlapped),
        interconnect_stall=ext(p1.breakdown.interconnect_stall,
                               p2.breakdown.interconnect_stall),
    )
    # extrapolate resource byte/flop totals, recompute utilizations
    flops = sum(op.flops for op in g_full.ops)
    hbm_bytes = sum(op.hbm_bytes for op in g_full.ops)

    def occ_of(p: ExecutionPlan) -> float:
        return p.util.interconnect * p.total_time

    noc_occ = ext(occ_of(p1), occ_of(p2))
    util = Utilization(
        hbm=min(hbm_bytes / (chip.hbm_bw * total), 1.0) if chip.hbm_bw else 0.0,
        interconnect=min(noc_occ / total, 1.0),
        flops=min(flops / (chip.total_flops * total), 1.0),
        achieved_tflops=flops / total / 1e12,
    )
    return ExecutionPlan(p2.graph, chip.name, design, p2.decisions,
                         p2.preload_order, p2.timing, total, breakdown, util,
                         extrapolated_from_layers=l2)


def compare_designs(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                    seq: int, phase: Phase = "decode",
                    designs=("Basic", "Static", "ELK-Dyn", "ELK-Full",
                             "Ideal"), **kw) -> dict[str, ExecutionPlan]:
    return {d: compile_model(cfg, chip, batch=batch, seq=seq, phase=phase,
                             design=d, **kw) for d in designs}
