"""Inter-core kernel fusion pass (DESIGN.md §8).

The ICCA chip's aggregate SRAM turns fusion from a vector-epilogue trick
into something that works for compute-intensive chains: a matmul ->
vector-activation -> matmul chain whose intermediate fits the combined
on-chip memory of the executing core group can run as ONE operator — the
intermediate is held in SRAM (partials staged over the interconnect by
the reduction of the second matmul's contraction split) instead of being
stored and reloaded between ops, and both weight matrices ride one HBM
preload pass.

This module contributes three pieces:

* ``find_fusable_chains`` / ``fuse_graph`` — graph pass emitting candidate
  :class:`FusedOp` nodes for every MLP-block chain (plain ``fc1 -> act ->
  fc2``, GLU ``gate_up -> act -> down``, MoE shared-expert ``shared_up ->
  shared_act -> shared_down``, RWKV channel-mix ``cm_k -> cm_act ->
  cm_v``), gated on the intermediate fitting ``chip.total_sram``.  The
  matcher is structural (op kinds, byte flow, weight provenance), so
  matmul -> vector *pairs* fuse too when ``pairs=True``.
* ``enumerate_fused_exec_plans`` — the fused-op Pareto curve, built by
  *zipping the stage matmuls' own generic Pareto curves*: each stage
  keeps the layout the generic enumerator found best for it and the
  intermediate is resharded stage-to-stage over the interconnect (with
  the activation applied in-stream).  Every pairing contributes a
  *fused* point (second-stage weights resident through the first, one
  merged preload window) AND a *composed* point (same stage plans,
  separate activation op, per-stage peak footprint only), so the §4.3
  allocator and §4.2 scheduler see both alternatives per window and
  pick fusion only where it beats preload overlap.
* cache signatures (``fusion_signature`` / ``graph_fusion_signature``) —
  threaded through the plan cache and allocation-window keys exactly like
  ``topo_signature``, so fusion-on and fusion-off compiles never share a
  stale entry.  ``FUSION_VERSION`` bumps invalidate everything at once.

The selection contract (never worse than fusion-off) is enforced one
level up: ``core.pipeline`` compiles the fused and unfused graphs against
one shared ``CompileContext`` and keeps the faster plan.
"""

from __future__ import annotations

import dataclasses

from repro.chip.config import ChipConfig
from repro.core.cost_model import AnalyticCostModel
from repro.core.graph import Op, OpGraph, TensorSpec
from repro.core.partition import _pareto, ExecPlan, enumerate_exec_plans

# Bump to invalidate every fusion-dependent cache entry (curve signatures,
# window keys, plan-cache keys) in one place.
FUSION_VERSION = 1


def fusion_signature(enabled: bool) -> tuple:
    """Plan-cache key component for the compile-level fusion knob."""
    return ("fusion", FUSION_VERSION if enabled else 0)


def graph_fusion_signature(graph: OpGraph) -> tuple:
    """Window-cache key component: whether (and how much of) the graph being
    scheduled is fused.  Mirrors ``topo_signature``'s role from the topology
    subsystem."""
    n = sum(1 for op in graph.ops if isinstance(op, FusedOp))
    return ("fusion", FUSION_VERSION if n else 0, n)


# ---------------------------------------------------------------------------
# the fused node
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedOp(Op):
    """A matmul -> vector [-> matmul] chain collapsed into one operator.

    The declared iteration space ``(M, FF)`` — output rows by the chain's
    staging width (the second matmul's contraction dim) — describes the
    op's *memory residency* for the preload side: inputs are ``x`` (spans
    M), ``w1`` (first matmul's weights+bias, spans FF) and, for triples,
    ``w2`` (second matmul's weights+bias, spans FF); both weight tensors
    keep ``from_hbm``, so the *generic* preload-plan enumerator prices
    them as one merged HBM pass with a single request latency — the fused
    preload curve falls out for free.  Execution is priced per *stage*
    (``enumerate_fused_exec_plans``): each stage matmul runs under its own
    generic split, connected by an interconnect reshard — a single split
    tuple over (M, FF) cannot express two good stage layouts at once (the
    output reduction would span the whole FF split).
    """
    parts: tuple[Op, ...] = ()
    inter_bytes: int = 0        # live intermediate bytes (whole chain)

    @property
    def curve_signature_extra(self) -> tuple:
        """Joins ``op_curve_signature`` so fused curves never collide with a
        plain matmul of the same shape.  Shape-only (no names/layers): the
        chain in every identical layer interns one curve."""
        return ("fused", FUSION_VERSION, self.inter_bytes,
                tuple((p.kind, p.dims, p.reduce_dims, p.flops, p.out_bytes)
                      for p in self.parts))


# ---------------------------------------------------------------------------
# chain detection
# ---------------------------------------------------------------------------

def _mm_with_hbm_weight(op: Op) -> bool:
    """A plain (m,n,k) matmul whose weight streams from HBM — excludes the
    2-dim attention BMMs (KV/score operands) by construction."""
    return (op.kind == "matmul" and len(op.dims) == 3
            and len(op.inputs) >= 2 and op.inputs[1].dims == (2, 1)
            and op.inputs[1].from_hbm)


def _vec_consumes(a: Op, b: Op) -> bool:
    """``b`` is a pure elementwise op over ``a``'s output (GLU activations
    read half the ``gate_up`` width).  A ``from_hbm`` input (RWKV's wkv
    state, SSM scan state, embedding tables) disqualifies: the op is a
    recurrence, not an activation."""
    if b.kind != "vector" or b.layer != a.layer or b.layer < 0:
        return False
    if b.preload_dep >= 0 or any(t.from_hbm for t in b.inputs):
        return False
    return b.inputs[0].bytes_total in (a.out_bytes, a.out_bytes // 2)


def _mm_closes(a: Op, b: Op, c: Op) -> bool:
    """``c`` down-projects ``b``'s output back: contraction width matches
    the intermediate (plain) or half the up-projection (GLU).

    The hourglass check (``a`` *strictly* expands, ``c`` contracts)
    rejects chains the byte flow alone can't: ``o -> ln2 -> gate_up`` /
    ``o -> ln2 -> router`` are structurally matmul -> vector -> matmul,
    but the vector op there is a norm sitting on the residual stream, not
    an activation on ``a``'s output (the op graph doesn't carry residual
    edges).  Square projections are always that pattern — an MLP
    up-projection widens."""
    if not _mm_with_hbm_weight(c) or c.layer != a.layer or c.preload_dep >= 0:
        return False
    if c.inputs[0].bytes_total != b.out_bytes or c.dims[0] != a.dims[0]:
        return False
    if a.dims[1] <= a.dims[2] or c.dims[1] > c.dims[2]:
        return False
    return a.dims[1] in (c.dims[2], 2 * c.dims[2])


def _fits_group_sram(a: Op, b: Op, chip: ChipConfig) -> bool:
    """§8 gate: the chain's live intermediate must fit the aggregate SRAM
    of the executing core group (the whole chip/pod here)."""
    return max(a.out_bytes, b.out_bytes) <= chip.total_sram


def find_fusable_chains(graph: OpGraph, chip: ChipConfig, *,
                        pairs: bool = False) -> list[tuple[int, int]]:
    """Non-overlapping ``[start, end)`` op-index spans of fusable chains,
    greedily longest-first (triples before pairs)."""
    ops = graph.ops
    chains: list[tuple[int, int]] = []
    i = 0
    while i < len(ops) - 1:
        a = ops[i]
        if _mm_with_hbm_weight(a) and _vec_consumes(a, ops[i + 1]):
            b = ops[i + 1]
            if (i + 2 < len(ops) and _mm_closes(a, b, ops[i + 2])
                    and _fits_group_sram(a, b, chip)):
                chains.append((i, i + 3))
                i += 3
                continue
            if pairs and a.dims[1] > a.dims[2] and _fits_group_sram(a, b, chip):
                chains.append((i, i + 2))
                i += 2
                continue
        i += 1
    return chains


def _make_fused(parts: tuple[Op, ...]) -> FusedOp:
    a, b = parts[0], parts[1]
    c = parts[2] if len(parts) == 3 else None
    x = a.inputs[0]
    inputs = [TensorSpec(x.name, (0,), x.bytes_total, x.from_hbm),
              TensorSpec("w1", (1,), sum(t.bytes_total for t in a.inputs[1:]),
                         a.inputs[1].from_hbm)]
    if c is not None:
        inputs.append(TensorSpec("w2", (1,),
                                 sum(t.bytes_total for t in c.inputs[1:]),
                                 c.inputs[1].from_hbm))
        dims = (a.dims[0], c.dims[2])
        reduce_dims: tuple[int, ...] = (1,)
        out_bytes = c.out_bytes
    else:
        dims = (a.dims[0], a.dims[1])
        reduce_dims = ()
        out_bytes = b.out_bytes
    # "l3.gate_up" + "act" + "down" -> "l3.gate_up+act+down": the layer-
    # invariant suffix (name.split(".", 1)[-1]) stays identical across
    # layers, so §4.4 order replay over identical layers keeps working.
    name = "+".join([a.name] + [p.name.split(".")[-1] for p in parts[1:]])
    return FusedOp(name, "matmul", a.layer, dims, reduce_dims,
                   sum(p.flops for p in parts), tuple(inputs), out_bytes,
                   a.preload_dep, parts=tuple(parts),
                   inter_bytes=max(a.out_bytes, b.out_bytes))


def fuse_graph(graph: OpGraph, chip: ChipConfig, *,
               pairs: bool = False) -> OpGraph:
    """Rewrite ``graph`` with every fusable chain collapsed to a FusedOp.

    ``preload_dep`` indices (MoE router late binding) are remapped to the
    new op positions; ``layer_span`` is recomputed so §4.4 layer-identity
    pruning sees the fused layer shape.  Returns ``graph`` unchanged (same
    object) when nothing fuses."""
    chains = find_fusable_chains(graph, chip, pairs=pairs)
    if not chains:
        return graph
    span_end = dict(chains)
    new_ops: list[Op] = []
    old2new = [0] * len(graph.ops)
    i = 0
    while i < len(graph.ops):
        end = span_end.get(i)
        if end is None:
            old2new[i] = len(new_ops)
            new_ops.append(graph.ops[i])
            i += 1
        else:
            for k in range(i, end):
                old2new[k] = len(new_ops)
            new_ops.append(_make_fused(tuple(graph.ops[i:end])))
            i = end
    for ni, op in enumerate(new_ops):
        if op.preload_dep >= 0 and old2new[op.preload_dep] != op.preload_dep:
            new_ops[ni] = dataclasses.replace(
                op, preload_dep=old2new[op.preload_dep])
    s, e = graph.layer_span
    new_span = (old2new[s], old2new[e - 1] + 1) if e > s else \
        (len(new_ops), len(new_ops))
    return OpGraph(graph.model, graph.phase, tuple(new_ops), new_span,
                   graph.num_layers)


# ---------------------------------------------------------------------------
# fused-op execution curve
# ---------------------------------------------------------------------------

def _stage_weight_resident(part: Op, plan: ExecPlan) -> int:
    """Per-core residency of a stage's weight operands under its plan
    (mirrors the generic enumerator's shared-tensor accounting)."""
    total = 0
    used, r = plan.cores_used, plan.chunk
    for t in part.inputs[1:]:
        tb = t.tile_bytes(plan.split)
        q = 1
        for dix in t.dims:
            q *= plan.split[dix]
        g = used // max(q, 1)
        if g <= 1 or r == 1:
            total += tb
        else:
            total += min(-(-tb // g) + 2 * -(-tb // r), tb)
    return total


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _project_split(op: FusedOp, chip: ChipConfig,
                   stages: list[tuple[Op, ExecPlan]]) -> tuple[int, int]:
    """Map the stage plans' layouts onto the FusedOp's (M, FF) residency
    space — ``enumerate_preload_plans`` prices weight shard fractions and
    the distribution phase off this split.  The weight split is taken as
    the *coarsest* across stages (every weight is at least that sharded)
    and the row split as the finest, so per-core preload space and
    distribution volume are over-, never under-estimated."""
    w_q = []
    for part, plan in stages:
        q = 1
        for dix in part.inputs[1].dims:
            q *= plan.split[dix]
        w_q.append(max(q, 1))
    s1 = min(min(w_q), _pow2_floor(op.dims[1]))
    s0 = min(min(plan.split[0] for _, plan in stages),
             _pow2_floor(op.dims[0]))
    while s0 * s1 > chip.num_cores:
        if s1 > 1:
            s1 //= 2
        else:
            s0 //= 2
    return (s0, s1)


def enumerate_fused_exec_plans(op: FusedOp, chip: ChipConfig,
                               cost: AnalyticCostModel | None = None,
                               max_plans: int = 48) -> list[ExecPlan]:
    """Pareto execute-state curve for a fused chain, fastest/biggest first.

    Stage matmuls are priced by the *generic* enumerator under their own
    layouts — a single split tuple over (M, FF) cannot serve both stages
    (the output reduction would span the entire FF split and its round
    count would dominate).  Every (stage-a plan, stage-c plan) pairing
    contributes up to two points:

    * ``fused=True`` — the chain runs as one operator: the intermediate
      is resharded stage-a-layout -> stage-c-layout over the interconnect
      with the activation applied in-stream (no separate SRAM pass, no
      separate issue), and the second stage's weights stay resident
      through the first — the price of the single merged preload window.
    * ``fused=False`` — the composed alternative with the same stage
      plans: a separate activation op between the stages and only the
      per-stage peak footprint held (the scheduler time-multiplexes SRAM
      between the stages' weights).

    The allocator's choice between them is the fuse-vs-footprint
    tradeoff; the fused point's exec-time edge (in-stream activation vs a
    separate vector op) is small — fusion's real win is the merged
    preload window the scheduler sees.
    """
    cost = cost or AnalyticCostModel(chip)
    cap = chip.usable_sram_per_core
    a = op.parts[0]
    c = op.parts[2] if len(op.parts) == 3 else None
    vec_flops = sum(p.flops for p in op.parts if p.kind != "matmul")
    t_vec, v_space = 0.0, 0
    for v in (p for p in op.parts if p.kind != "matmul"):
        vp = enumerate_exec_plans(v, chip, cost, max_plans)[0]
        t_vec += vp.time
        v_space = max(v_space, vp.space)
    curve_a = enumerate_exec_plans(a, chip, cost, max_plans)
    raw: list[ExecPlan] = []
    if c is None:
        for pa in curve_a:
            split = _project_split(op, chip, [(a, pa)])
            # epilogue fusion: the activation runs on the VPU against the
            # output tile still in registers — its compute adds, its SRAM
            # pass and issue overhead vanish
            t_act = vec_flops / pa.cores_used / chip.core_flops_vector
            raw.append(ExecPlan(split, pa.chunk, pa.cores_used,
                                pa.time + t_act, pa.space,
                                pa.noc_exec_bytes, pa.sram_remote_bytes,
                                fused=True))
            raw.append(ExecPlan(split, pa.chunk, pa.cores_used,
                                pa.time + t_vec, max(pa.space, v_space),
                                pa.noc_exec_bytes, pa.sram_remote_bytes,
                                fused=False))
    else:
        curve_c = enumerate_exec_plans(c, chip, cost, max_plans)
        for pa in curve_a:
            for pc in curve_c:
                split = _project_split(op, chip, [(a, pa), (c, pc)])
                used = max(pa.cores_used, pc.cores_used)
                chunk = max(pa.chunk, pc.chunk)
                noc = pa.noc_exec_bytes + pc.noc_exec_bytes
                rem = pa.sram_remote_bytes + pc.sram_remote_bytes
                raw.append(ExecPlan(split, chunk, used,
                                    pa.time + t_vec + pc.time,
                                    max(pa.space, v_space, pc.space),
                                    noc, rem, fused=False))
                f_space = max(pa.space + _stage_weight_resident(c, pc),
                              pc.space)
                if f_space > cap:
                    continue
                h_core = -(-op.inter_bytes
                           // min(pa.cores_used, pc.cores_used))
                t_resh = (cost.dist_time(h_core)
                          + vec_flops / used / chip.core_flops_vector)
                raw.append(ExecPlan(split, chunk, used,
                                    pa.time + pc.time + t_resh, f_space,
                                    noc + op.inter_bytes, rem + h_core,
                                    fused=True))
    plans = _pareto(raw, lambda p: p.time, lambda p: p.space)
    if len(plans) > max_plans:
        idxs = [int(i * (len(plans) - 1) / (max_plans - 1))
                for i in range(max_plans)]
        plans = [plans[i] for i in sorted(set(idxs))]
    return plans
