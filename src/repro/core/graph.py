"""Operator graph extraction (the paper's ONNX-frontend equivalent, §5.1).

Builds the per-model operator list the ELK scheduler consumes, with per-op
iteration spaces, FLOPs, HBM load bytes and input tensor sharing structure.
The same ``ModelConfig`` drives the JAX runtime, so the graph and the real
model agree on shapes by construction.

Conventions
-----------
* ``Op.dims`` is the partitionable iteration space (e.g. ``(M, N, K)`` for a
  matmul).  ``reduce_dims`` indexes reduction dims within ``dims``.
* Each input ``TensorSpec.dims`` lists which iteration dims the tensor spans;
  cores whose tiles differ only in non-spanned dims *share* the tensor —
  that sharing group size ``g`` is what drives broadcast-vs-shift tradeoffs
  (paper Fig. 3).
* ``from_hbm`` marks data loaded from off-chip memory (weights, KV cache,
  recurrent state).  Activations flowing between ops stay on-chip
  (the ICCA chip's large SRAM holds whole intermediates, paper §8).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Literal, Optional

from repro.models.config import ModelConfig

Phase = Literal["decode", "prefill", "train_fwd"]


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    name: str
    dims: tuple[int, ...]          # indices into Op.dims spanned by this tensor
    bytes_total: int               # whole-tensor bytes (all cores combined)
    from_hbm: bool

    def tile_bytes(self, split: tuple[int, ...]) -> int:
        """Per-tile bytes under a dim split (ceil per spanned dim)."""
        q = 1
        for d in self.dims:
            q *= split[d]
        return -(-self.bytes_total // max(q, 1))


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    kind: Literal["matmul", "vector"]
    layer: int                     # -1 for embed / head / frontends
    dims: tuple[int, ...]
    reduce_dims: tuple[int, ...]
    flops: float
    inputs: tuple[TensorSpec, ...]
    out_bytes: int
    # MoE late binding (§7 "Apply ELK to MoE"): preload may not start before
    # this op index has finished executing (the router).  -1 = no constraint.
    preload_dep: int = -1

    @property
    def hbm_bytes(self) -> int:
        return sum(t.bytes_total for t in self.inputs if t.from_hbm)

    @property
    def act_bytes(self) -> int:
        return sum(t.bytes_total for t in self.inputs if not t.from_hbm)


@dataclasses.dataclass(frozen=True)
class OpGraph:
    model: str
    phase: Phase
    ops: tuple[Op, ...]
    layer_span: tuple[int, int]    # [start, end) op indices of layer 0
    num_layers: int                # identical-layer count (for §4.4 pruning)

    def __len__(self) -> int:
        return len(self.ops)

    def hbm_heavy_threshold(self) -> float:
        """§4.4: reorder only ops whose HBM tensor size is above average."""
        total = sum(op.hbm_bytes for op in self.ops)
        return total / max(len(self.ops), 1)

    def hbm_heavy(self, idx: int) -> bool:
        return self.ops[idx].hbm_bytes > self.hbm_heavy_threshold()


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _mm(name: str, layer: int, m: int, n: int, k: int, *,
        w_hbm: bool = True, bias: bool = False, dt: int = 2,
        act_name: str = "x", extra_flop_k: int = 0,
        preload_dep: int = -1) -> Op:
    """A (m,k)@(k,n) matmul; weight loaded from HBM unless ``w_hbm=False``."""
    flops = 2.0 * m * n * (k + extra_flop_k)
    inputs = [
        TensorSpec(act_name, (0, 2), m * k * dt, False),
        TensorSpec("w", (2, 1), k * n * dt, w_hbm),
    ]
    if bias:
        inputs.append(TensorSpec("b", (1,), n * dt, w_hbm))
    return Op(name, "matmul", layer, (m, n, k), (2,), flops,
              tuple(inputs), m * n * dt, preload_dep)


def _bmm_attn(name: str, layer: int, rows: int, heads: int, ctx: int,
              head_dim: int, kv_bytes: int, *, kv_hbm: bool,
              score: bool, dt: int = 2) -> Op:
    """Attention BMM: iteration space (rows*heads, ctx), inner dim head_dim.

    ``score=True`` is q@K^T (output = the (rows*heads, ctx) score matrix, no
    reduction over ctx); ``score=False`` is scores@V (ctx reduced, output =
    (rows*heads, head_dim)).  The KV tensor spans *both* dims: per paper
    §3.2 the KV cache has no data reuse among requests, so no core shares it
    (each core streams its own slice) — its broadcast fraction is moot but
    its preload footprint is real."""
    flops = 2.0 * rows * heads * ctx * head_dim
    out = (rows * heads * ctx * dt) if score else (rows * heads * head_dim * dt)
    inputs = (
        TensorSpec("q", (0,), rows * heads * head_dim * dt, False),
        TensorSpec("kv", (0, 1), kv_bytes, kv_hbm),
    )
    reduce = () if score else (1,)
    return Op(name, "matmul", layer, (rows * heads, ctx), reduce, flops,
              inputs, out)


def _vec(name: str, layer: int, tokens: int, width: int, *,
         flop_mult: float = 8.0, hbm_bytes: int = 0, dt: int = 2) -> Op:
    """Elementwise / softmax / norm op over (tokens, width)."""
    n = tokens * width
    inputs = [TensorSpec("x", (0,), n * dt, False)]
    if hbm_bytes:
        inputs.append(TensorSpec("w", (1,), hbm_bytes, True))
    return Op(name, "vector", layer, (tokens, width), (), flop_mult * n,
              tuple(inputs), n * dt)


def build_graph(cfg: ModelConfig, *, batch: int, seq: int,
                phase: Phase = "decode") -> OpGraph:
    """Build the operator list for one step of ``phase``.

    decode:    one new token per request; ctx = ``seq`` (KV read from HBM).
    prefill:   full-sequence forward; attention O(seq^2), weights from HBM.
    train_fwd: like prefill over batch*seq tokens (paper Fig. 24 examines the
               forward pass of training; bwd has the same structure).
    """
    dt = 2  # bf16
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads

    if phase == "decode":
        rows, ctx = batch, seq
    else:
        rows, ctx = batch * seq, seq

    ops: list[Op] = []

    def idx() -> int:
        return len(ops)

    # ---- embedding ---------------------------------------------------------
    emb_rows = rows if cfg.frontend == "none" else rows
    ops.append(_vec("embed", -1, emb_rows, d,
                    hbm_bytes=min(cfg.vocab_size, emb_rows) * d * dt))
    if cfg.vision_patches and phase != "decode":
        ops.append(_vec("vision_patches", -1, batch * cfg.vision_patches, d,
                        hbm_bytes=0))

    # ---- encoder (whisper) -------------------------------------------------
    enc_ctx = cfg.encoder_seq or 0
    if cfg.encoder_layers:
        erows = batch * enc_ctx
        for li in range(cfg.encoder_layers):
            L = -1  # encoder ops are outside the identical-decoder-layer span
            ops.append(_vec(f"enc{li}.ln1", L, erows, d))
            ops.append(_mm(f"enc{li}.qkv", L, erows, 3 * d, d, bias=True))
            ops.append(_bmm_attn(f"enc{li}.score", L, erows, nq, enc_ctx, hd,
                                 batch * enc_ctx * d * dt, kv_hbm=False,
                                 score=True))
            ops.append(_vec(f"enc{li}.softmax", L, erows * nq, enc_ctx))
            ops.append(_bmm_attn(f"enc{li}.attnv", L, erows, nq, enc_ctx, hd,
                                 batch * enc_ctx * d * dt, kv_hbm=False,
                                 score=False))
            ops.append(_mm(f"enc{li}.o", L, erows, d, d))
            ops.append(_vec(f"enc{li}.ln2", L, erows, d))
            ops.append(_mm(f"enc{li}.fc1", L, erows, cfg.d_ff, d, bias=True))
            ops.append(_mm(f"enc{li}.fc2", L, erows, d, cfg.d_ff, bias=True))

    # ---- decoder layers ----------------------------------------------------
    layer_start = idx()
    layer_end = layer_start
    for li in range(cfg.num_layers):
        if cfg.rwkv:
            _rwkv_layer(ops, cfg, li, rows, dt)
        else:
            _attn_layer(ops, cfg, li, rows, ctx, batch, phase, dt)
            if cfg.hybrid_parallel_ssm:
                _ssm_branch(ops, cfg, li, rows, batch, dt)
            if cfg.encoder_layers:
                _cross_attn(ops, cfg, li, rows, batch, enc_ctx, dt)
            _mlp(ops, cfg, li, rows, dt)
        if li == 0:
            layer_end = idx()

    # ---- head --------------------------------------------------------------
    head_rows = batch if phase == "decode" else rows
    ops.append(_vec("final_norm", -1, head_rows, d))
    ops.append(_mm("lm_head", -1, head_rows, cfg.vocab_size, d))

    return OpGraph(cfg.name, phase, tuple(ops), (layer_start, layer_end),
                   cfg.num_layers)


def _attn_layer(ops: list[Op], cfg: ModelConfig, li: int, rows: int,
                ctx: int, batch: int, phase: Phase, dt: int) -> None:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    win = cfg.sliding_window if (cfg.sliding_window and
                                 cfg.swa_layers == "all") else 0
    actx = min(ctx, win) if win else ctx
    if phase != "decode":
        # causal average context length
        actx = min(ctx, win) if win else ctx
        eff_ctx = actx if win else max(ctx // 2, 1)
    else:
        eff_ctx = actx

    ops.append(_vec(f"l{li}.ln1", li, rows, d))
    ops.append(_mm(f"l{li}.q", li, rows, nq * hd, d, bias=cfg.qkv_bias))
    ops.append(_mm(f"l{li}.kv", li, rows, 2 * nkv * hd, d, bias=cfg.qkv_bias))
    extra = 4.0 if cfg.qk_norm else 2.0  # rope (+qk rmsnorm)
    ops.append(_vec(f"l{li}.rope", li, rows, (nq + nkv) * hd,
                    flop_mult=extra))
    kv_bytes = batch * nkv * eff_ctx * hd * dt
    kv_hbm = phase == "decode"   # decode streams the KV cache from HBM
    ops.append(_bmm_attn(f"l{li}.score", li, rows, nq, eff_ctx, hd,
                         kv_bytes, kv_hbm=kv_hbm, score=True, dt=dt))
    ops.append(_vec(f"l{li}.softmax", li, rows * nq, eff_ctx, flop_mult=6.0))
    ops.append(_bmm_attn(f"l{li}.attnv", li, rows, nq, eff_ctx, hd,
                         kv_bytes, kv_hbm=kv_hbm, score=False, dt=dt))
    ops.append(_mm(f"l{li}.o", li, rows, d, nq * hd))


def _ssm_branch(ops: list[Op], cfg: ModelConfig, li: int, rows: int,
                batch: int, dt: int) -> None:
    """Hymba's parallel mamba branch (in/out proj + selective scan)."""
    d, st = cfg.d_model, cfg.ssm_state
    ops.append(_mm(f"l{li}.ssm_in", li, rows, 2 * d, d))
    # selective scan: state (d x st) per request read+written each step
    state_bytes = batch * d * st * 4  # fp32 state
    n = rows * d
    inputs = (TensorSpec("x", (0,), n * dt, False),
              TensorSpec("state", (0,), state_bytes, True))
    ops.append(Op(f"l{li}.ssm_scan", "vector", li, (rows, d), (),
                  10.0 * n * st, inputs, n * dt))
    ops.append(_mm(f"l{li}.ssm_out", li, rows, d, d))


def _cross_attn(ops: list[Op], cfg: ModelConfig, li: int, rows: int,
                batch: int, enc_ctx: int, dt: int) -> None:
    d, hd, nq = cfg.d_model, cfg.resolved_head_dim, cfg.num_heads
    ops.append(_vec(f"l{li}.ln_x", li, rows, d))
    ops.append(_mm(f"l{li}.xq", li, rows, d, d))
    kv_bytes = batch * enc_ctx * d * dt
    ops.append(_bmm_attn(f"l{li}.xscore", li, rows, nq, enc_ctx, hd,
                         kv_bytes, kv_hbm=True, score=True, dt=dt))
    ops.append(_vec(f"l{li}.xsoftmax", li, rows * nq, enc_ctx, flop_mult=6.0))
    ops.append(_bmm_attn(f"l{li}.xattnv", li, rows, nq, enc_ctx, hd,
                         kv_bytes, kv_hbm=True, score=False, dt=dt))
    ops.append(_mm(f"l{li}.xo", li, rows, d, d))


def _mlp(ops: list[Op], cfg: ModelConfig, li: int, rows: int, dt: int) -> None:
    d = cfg.d_model
    ops.append(_vec(f"l{li}.ln2", li, rows, d))
    if cfg.is_moe_layer(li):
        e, k = cfg.moe_experts, cfg.moe_top_k
        mff = cfg.moe_hidden()
        router_idx = len(ops)
        ops.append(_mm(f"l{li}.router", li, rows, e, d))
        # tokens*topk rows through touched experts; weights = touched experts
        touched = min(e, rows * k)
        nmat = 3 if cfg.gated_mlp else 2
        w_bytes = touched * nmat * d * mff * dt
        m = rows * k
        flops = 2.0 * m * nmat * d * mff
        inputs = (TensorSpec("x", (0, 2), m * d * dt, False),
                  TensorSpec("w_experts", (2, 1), w_bytes, True))
        ops.append(Op(f"l{li}.experts", "matmul", li, (m, mff, d), (2,),
                      flops, inputs, m * d * dt, preload_dep=router_idx))
        if cfg.moe_shared_d_ff:
            sff = cfg.moe_shared_d_ff
            nm = 3 if cfg.gated_mlp else 2
            ops.append(_mm(f"l{li}.shared_up", li, rows, (nm - 1) * sff, d))
            ops.append(_vec(f"l{li}.shared_act", li, rows, sff, flop_mult=4.0))
            ops.append(_mm(f"l{li}.shared_down", li, rows, d, sff))
    else:
        ff = cfg.d_ff
        if cfg.gated_mlp:
            ops.append(_mm(f"l{li}.gate_up", li, rows, 2 * ff, d))
            ops.append(_vec(f"l{li}.act", li, rows, ff, flop_mult=4.0))
            ops.append(_mm(f"l{li}.down", li, rows, d, ff))
        else:
            ops.append(_mm(f"l{li}.fc1", li, rows, ff, d,
                           bias=cfg.qkv_bias))
            ops.append(_vec(f"l{li}.act", li, rows, ff, flop_mult=2.0))
            ops.append(_mm(f"l{li}.fc2", li, rows, d, ff,
                           bias=cfg.qkv_bias))


def _rwkv_layer(ops: list[Op], cfg: ModelConfig, li: int, rows: int,
                dt: int) -> None:
    d, ff = cfg.d_model, cfg.d_ff
    nh = cfg.num_heads
    hd = d // max(nh, 1)
    ops.append(_vec(f"l{li}.ln1", li, rows, d))
    for proj in ("r", "k", "v", "g"):
        ops.append(_mm(f"l{li}.{proj}", li, rows, d, d))
    # wkv recurrence: per-head state hd x hd read+written (fp32)
    state_bytes = rows * nh * hd * hd * 4
    n = rows * d
    inputs = (TensorSpec("rkv", (0,), 3 * n * dt, False),
              TensorSpec("state", (0,), state_bytes, True))
    ops.append(Op(f"l{li}.wkv", "vector", li, (rows, d), (),
                  16.0 * rows * nh * hd * hd, inputs, n * dt))
    ops.append(_mm(f"l{li}.out", li, rows, d, d))
    ops.append(_vec(f"l{li}.ln2", li, rows, d))
    ops.append(_mm(f"l{li}.cm_k", li, rows, ff, d))
    ops.append(_vec(f"l{li}.cm_act", li, rows, ff, flop_mult=2.0))
    ops.append(_mm(f"l{li}.cm_v", li, rows, d, ff))
