"""Lower an ELK ``ExecutionPlan`` into runtime knobs (DESIGN.md §3).

Two integration levels:

* ``pod_plan``  — read the TPU pod as one ICCA chip (chips = cores, ICI =
  interconnect, the sharded weight store = off-chip memory), run the
  paper's scheduler on the arch's decode/prefill graph, and extract the
  runtime knobs the serving/training stacks consume: the **prefetch
  depth** (paper: preload number) for the gather-ahead window and the
  **resident fraction** (paper: preload-state fraction f) that decides
  FSDP sharding of block weights.

* ``vmem_plan`` — read one TPU chip as an ICCA chip at the VMEM level and
  pick Pallas matmul block shapes (bm, bn, bk): the (bm, bn) fp32
  accumulator + current operand tiles are the execution space, the grid
  pipeline's in-flight next blocks are the preload space.  The search is
  the paper's §4.3 greedy on a closed-form cost (HBM traffic per FLOP),
  constrained to MXU-aligned multiples of 128.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.chip.config import (MB, ChipConfig, tpu_v5e_pod, tpu_v5e_pod_hier,
                               tpu_v5e_vmem)
from repro.core.elk import compile_model
from repro.core.graph import Phase
from repro.core.plan import ExecutionPlan
from repro.models.config import ModelConfig

# share of the on-chip store the gather-ahead window may occupy; the other
# half stays execution state (the §3 space split at pod level)
_PREFETCH_SRAM_SHARE = 0.5


@dataclasses.dataclass(frozen=True)
class PodKnobs:
    """Runtime knobs for the pod-level ELK realization."""
    prefetch_depth: int          # gather-ahead window (preload number p)
    resident_fraction: float     # preload-state fraction f (1/k of weights)
    fsdp: bool                   # f < 1 => weights stay sharded (ZeRO-3)
    design: str = "ELK-Full"
    # pipeline mode (DESIGN.md §7): filled when the pod plans the graph as
    # pipeline stages across its chips instead of one flat core pool
    num_stages: int = 1
    stage_boundaries: tuple = ()     # layer cut points: stage s owns
    #                                  [boundary[s-1], boundary[s])
    microbatch: int = 0              # requests per microbatch
    microbatches: int = 1            # concurrent microbatch groups
    interval_s: float = 0.0          # steady per-microbatch interval
    batch_interval_s: float = 0.0    # one decode round of the whole batch
    # hybrid mode (DESIGN.md §9): per-stage tensor-parallel width and
    # data-parallel replica count (all 1s for the pure pipeline)
    stage_widths: tuple = ()
    stage_replicas: tuple = ()


def _plan_knobs(plan: ExecutionPlan, chip: ChipConfig) -> tuple[int, float]:
    """(prefetch depth in layer-blocks, resident fraction) of one plan.

    The prefetch-depth clamp is derived from capacity, not magic numbers:
    the gather-ahead window may hold at most the layer-blocks that fit in
    the prefetch share of the chip's on-chip store, and never fewer than
    one block — the window cannot be empty while an op is executing (§4.5:
    an operator must be preloaded before it executes).
    """
    lo, hi = plan.graph.layer_span
    ops_per_layer = max(hi - lo, 1)
    p_ops = max(plan.mean_preload_number, 0.0)
    per_layer_hbm = sum(op.hbm_bytes
                        for op in plan.graph.ops[lo:hi]) or 1
    cap_layers = max(
        int(chip.total_sram * _PREFETCH_SRAM_SHARE) // per_layer_hbm, 1)
    p_layers = min(max(math.ceil(p_ops / ops_per_layer), 1), cap_layers)
    fr = [d.preload_plan.frac for d in plan.decisions
          if d.preload_plan is not None and plan.graph.ops[d.op_idx].hbm_bytes]
    f = sum(fr) / len(fr) if fr else 1.0
    return p_layers, f


def pod_plan(cfg: ModelConfig, *, batch: int, seq: int,
             phase: Phase = "decode", num_chips: int = 256,
             design: str = "ELK-Full", chip: Optional[ChipConfig] = None,
             mode: str = "flat",
             num_stages: Optional[int] = None,
             widths: Optional[tuple] = None,
             replicas: Optional[tuple] = None) -> PodKnobs:
    """Run the faithful ELK compiler against the pod model and translate
    its decisions to runtime knobs.

    ``mode="flat"`` (default) reads the whole pod as one ICCA chip, exactly
    as before.  ``mode="pipeline"`` partitions the layer stack into
    pipeline stages across the pod's chips (``core.pipeline_pod``) and
    additionally returns the stage boundaries, microbatch knobs and the
    steady-state interval the serving stack sizes admission from.
    ``mode="hybrid"`` runs the joint (cut x width x replicas x microbatch)
    search (DESIGN.md §9); never worse than ``"pipeline"``, bit-identical
    to it when ``widths``/``replicas`` are pinned to ``(1,)``.

    Repeat calls for the same (model, shape, design) hit the process-level
    plan caches (DESIGN.md §2, §7), so the serving/training stacks can ask
    for knobs on the request path without recompiling.
    """
    if mode not in ("flat", "pipeline", "hybrid"):
        raise ValueError(f"unknown pod_plan mode {mode!r}")
    if mode in ("pipeline", "hybrid"):
        from repro.core.pipeline_pod import plan_hybrid, plan_pipeline
        chip = chip or tpu_v5e_pod_hier(num_chips)
        if mode == "hybrid":
            pp = plan_hybrid(cfg, chip, batch=batch, seq=seq, phase=phase,
                             design=design, widths=widths, replicas=replicas)
        else:
            pp = plan_pipeline(cfg, chip, batch=batch, seq=seq, phase=phase,
                               design=design, num_stages=num_stages)
        # knobs from the bottleneck stage: its plan paces the pipeline
        bottleneck = max(pp.stages, key=lambda st: st.effective_interval)
        flat = pp.num_stages == 1 and pp.stages[0].chips == 1
        member = chip if flat else chip.chip_view().chip
        depth, f = _plan_knobs(bottleneck.plan, member)
        return PodKnobs(prefetch_depth=depth, resident_fraction=f,
                        fsdp=f < 0.999, design=design,
                        num_stages=pp.num_stages,
                        stage_boundaries=tuple(st.layers[1]
                                               for st in pp.stages),
                        microbatch=pp.microbatch,
                        microbatches=pp.microbatches,
                        interval_s=pp.interval,
                        batch_interval_s=pp.batch_interval,
                        stage_widths=tuple(st.width for st in pp.stages),
                        stage_replicas=tuple(st.replicas
                                             for st in pp.stages))
    chip = chip or tpu_v5e_pod(num_chips)
    plan = compile_model(cfg, chip, batch=batch, seq=seq, phase=phase,
                         design=design, max_orders=8)
    # preload number: ops resident in preload state while one executes.
    # The pod runtime prefetches whole layer-blocks, so convert the mean
    # op-level preload number to layers: ops-per-layer is the graph period.
    p_layers, f = _plan_knobs(plan, chip)
    return PodKnobs(prefetch_depth=p_layers, resident_fraction=f,
                    fsdp=f < 0.999, design=design)


# ---------------------------------------------------------------------------
# VMEM-level block planning for the Pallas kernels
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VmemPlan:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int              # execution + preload footprint claimed
    hbm_traffic: int             # bytes moved for the whole matmul


def _align(v: int, a: int = 128) -> int:
    return max(a, (v // a) * a)


def vmem_plan(m: int, n: int, k: int, *,
              chip: Optional[ChipConfig] = None,
              dtype_bytes: int = 2,
              vmem_budget: Optional[int] = None) -> VmemPlan:
    """Choose (bm, bn, bk) for ``elk_matmul``.

    VMEM model (the ELK §3 split): execution space = fp32 accumulator
    (bm*bn*4) + current operand tiles; preload space = the next operand
    tiles in flight (Pallas double-buffers inputs => 2x operand bytes).

    Cost = HBM traffic: x is read N/bn times, y is read M/bm times, out
    written once: larger (bm, bn) divides re-reads; larger bk amortizes
    accumulator flushes (already 1 here) but enlarges operand tiles —
    the greedy therefore grows bm=bn first (quadratic reuse win), then bk.
    """
    chip = chip or tpu_v5e_vmem()
    budget = vmem_budget or int(chip.sram_per_core * 0.75)

    def footprint(bm, bn, bk):
        acc = bm * bn * 4
        operands = (bm * bk + bk * bn) * dtype_bytes
        return acc + 2 * operands          # double-buffered preload

    def traffic(bm, bn, bk):
        xn = math.ceil(n / bn)             # x re-reads
        ym = math.ceil(m / bm)             # y re-reads
        return (m * k * xn + k * n * ym) * dtype_bytes + m * n * dtype_bytes

    bm = bn = bk = 128
    best = (bm, bn, bk)
    # greedy doubling along the steepest-traffic-reduction axis (§4.3's
    # delta rule with signs flipped: grow the dim with best bytes-saved
    # per VMEM-byte-spent)
    while True:
        cands = []
        for dim in ("m", "n", "k"):
            nb = {"m": (min(2 * bm, _align(m)), bn, bk),
                  "n": (bm, min(2 * bn, _align(n)), bk),
                  "k": (bm, bn, min(2 * bk, _align(k)))}[dim]
            if nb == (bm, bn, bk):
                continue
            if footprint(*nb) > budget:
                continue
            saved = traffic(bm, bn, bk) - traffic(*nb)
            spent = footprint(*nb) - footprint(bm, bn, bk)
            cands.append((saved / max(spent, 1), nb))
        if not cands:
            break
        gain, nb = max(cands, key=lambda c: c[0])
        if gain <= 0:
            break
        bm, bn, bk = nb
        best = nb
    bm, bn, bk = best
    return VmemPlan(bm, bn, bk, footprint(bm, bn, bk), traffic(bm, bn, bk))
