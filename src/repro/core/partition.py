"""Operator partition plans (paper §4.3 intra-operator tradeoffs, §5.2).

Execute-state plans (Tradeoff 1, Fig. 11)
-----------------------------------------
A plan splits the op's iteration space over cores, ``<s1,s2,...>`` (§5: "evenly
slices each dimension"), with a rotation-chunk count ``r`` following the
compute-shift execution model of T10 [34]:

* every input tensor spanned by only a subset of dims is *shared* by a group
  of ``g = P/q`` cores (Fig. 3);
* ``r == 1``: the shared tile is fully resident per core during execution
  (fast, big execution space);
* ``r > 1``: the tile is rotated between group peers in ``1/r`` chunks
  (small execution space = own shard + double-buffered chunk, but inter-core
  traffic during execution + per-chunk issue overhead + SRAM port contention).

Larger execution space => faster execution and less exec-time interconnect
traffic — exactly Fig. 5's measured correlation.

Preload-state plans (Tradeoffs 2+3)
-----------------------------------
Given an execute-state plan, a preload fraction ``f`` picks how much of each
shared tile the HBM controllers broadcast per core at preload time
(paper: split a tile shared by 4 cores into 1, 2 or 4 chunks => each core
receives 1, 1/2, 1/4).  The *data-distribution phase* fetches the rest from
peers when the op transitions preload-state -> execute-state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

from repro.chip.config import ChipConfig
from repro.core.cost_model import AnalyticCostModel
from repro.core.graph import Op

_CHUNKS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    split: tuple[int, ...]
    chunk: int
    cores_used: int
    time: float            # contention-free per-op execution time
    space: int             # per-core execution space (bytes)
    noc_exec_bytes: int    # total inter-core volume during execution
    sram_remote_bytes: int # per-core bytes served to peers (contention ③)
    # True when this point runs the chain of a FusedOp as one SRAM pass
    # (core/fusion.py); False on every plain-op plan and on the composed
    # (store-reload) alternatives a fused curve carries.
    fused: bool = False

    def key(self) -> tuple:
        return (self.split, self.chunk)


@dataclasses.dataclass(frozen=True)
class PreloadPlan:
    frac: float
    space: int                 # per-core preload space (bytes)
    dist_time: float           # data-distribution time (preload->exec state)
    noc_dist_bytes: int        # inter-core volume of the distribution phase
    noc_preload_bytes: int     # interconnect bytes HBM-controllers -> cores
    hbm_bytes: int             # off-chip read volume


# ---------------------------------------------------------------------------

def op_curve_signature(op: Op) -> tuple:
    """Hashable key capturing everything plan enumeration depends on.

    Identical layers produce ops with identical signatures (only ``name``/
    ``layer``/``preload_dep`` differ), so one curve computation serves every
    repetition — the ``PlanCurveCache`` in ``core.pipeline`` keys on this.

    Op subclasses that enumerate differently (``core.fusion.FusedOp``)
    expose ``curve_signature_extra``; appending it keeps a fused chain from
    ever sharing a curve with a plain op of the same outer shape.
    """
    base = (op.kind, op.dims, op.reduce_dims, op.flops, op.out_bytes,
            tuple((t.dims, t.bytes_total, t.from_hbm) for t in op.inputs))
    extra = getattr(op, "curve_signature_extra", None)
    return base if extra is None else base + (extra,)


def _pow2_splits(dim: int, cores: int) -> list[int]:
    out, s = [], 1
    while s <= min(dim, cores):
        out.append(s)
        s *= 2
    return out


def _split_iter(dims: tuple[int, ...], cores: int) -> Iterator[tuple[int, ...]]:
    choices = [_pow2_splits(d, cores) for d in dims]

    def rec(i: int, prod: int, acc: list[int]):
        if i == len(dims):
            yield tuple(acc)
            return
        for s in choices[i]:
            if prod * s > cores:
                break
            acc.append(s)
            yield from rec(i + 1, prod * s, acc)
            acc.pop()

    yield from rec(0, 1, [])


def _pareto(plans, time_of, space_of):
    """Keep plans where no other plan is <= in both time and space."""
    plans = sorted(plans, key=lambda p: (space_of(p), time_of(p)))
    out, best_t = [], math.inf
    for p in plans:
        t = time_of(p)
        if t < best_t - 1e-15:
            out.append(p)
            best_t = t
    # out is sorted by increasing space, decreasing time; re-sort by space desc
    # so index 0 = fastest/biggest (allocator starts there and downgrades).
    return list(reversed(out))


# ---------------------------------------------------------------------------

def enumerate_exec_plans(op: Op, chip: ChipConfig,
                         cost: AnalyticCostModel | None = None,
                         max_plans: int = 48) -> list[ExecPlan]:
    """All Pareto-optimal execute-state plans, fastest (max space) first."""
    cost = cost or AnalyticCostModel(chip)
    cap = chip.usable_sram_per_core
    raw: list[ExecPlan] = []
    raw_spill: list[tuple] = []
    for split in _split_iter(op.dims, chip.num_cores):
        used = 1
        for s in split:
            used *= s
        tile_dims = tuple(-(-d // s) for d, s in zip(op.dims, split))
        tile_flops = op.flops / used
        chunk_opts = _CHUNKS if op.kind == "matmul" else (1,)
        for r in chunk_opts:
            space = -(-op.out_bytes // used)
            noc_total = 0
            remote_per_core = 0
            read_bytes = space
            rounds = 0
            feasible = True
            for t in op.inputs:
                tb = t.tile_bytes(split)
                q = 1
                for dix in t.dims:
                    q *= split[dix]
                g = used // max(q, 1)
                read_bytes += tb
                if g <= 1:
                    space += tb
                    continue
                if r == 1:
                    resident = tb                      # full replication
                else:
                    resident = -(-tb // g) + 2 * -(-tb // r)
                    if resident > tb:
                        resident = tb
                space += resident
                moved = tb - (-(-tb // g))             # (g-1)/g of the tile
                if r > 1:
                    # each of the q distinct tiles visits its g-1 group peers
                    noc_total += tb * (g - 1) * q
                    remote_per_core += moved
                    rounds += r * (g - 1)
            # reduction: partial outputs combined across reduce-split cores
            red = 1
            for dix in op.reduce_dims:
                red *= split[dix]
            if red > 1:
                red_bytes = op.out_bytes // max(used // red, 1)
                noc_total += red_bytes * (red - 1)
                remote_per_core += -(-op.out_bytes // used) * 2
                rounds += red - 1
            if space > cap:
                feasible = False
            if not feasible:
                # remember the most-compact infeasible plan: ops whose
                # minimum tile exceeds per-core SRAM (trillion-param MoE
                # experts on an IPU-class chip) fall back to a *spill plan*
                # — per-chunk streaming through SRAM, modeled by the
                # SRAM-feed bound in tile_time with r = ceil(space/cap).
                raw_spill.append((space, split, r, tile_dims, tile_flops,
                                  read_bytes, noc_total, remote_per_core,
                                  rounds))
                continue
            t_tile = cost.tile_time(op.kind, tile_dims, tile_flops,
                                    read_bytes, chunks=max(r, 1) + rounds)
            # topology-aware rotation cost: neighbor transfers on flat
            # topologies, stretched by slow-tier crossings on hierarchical
            # ones (cost_model delegates to chip.topo)
            t_rot = cost.rot_time(remote_per_core, rounds=max(rounds, 1))
            if chip.sram_port_blocking and remote_per_core:
                # footnote 2: remote reads pause local execution
                t_tile += remote_per_core / chip.sram_bw_per_core
            raw.append(ExecPlan(split, r, used, t_tile + t_rot, space,
                                noc_total, remote_per_core))
    if not raw and raw_spill:
        # spill plan: stream the tile through SRAM in ceil(space/cap)
        # rounds; claims the full SRAM and pays the extra chunk overhead
        space, split, r, tile_dims, tile_flops, read_bytes, noc_total, \
            remote_per_core, rounds = min(raw_spill, key=lambda t: t[0])
        spill_rounds = -(-space // cap)
        t_tile = cost.tile_time(op.kind, tile_dims, tile_flops,
                                read_bytes,
                                chunks=max(r, 1) + rounds + spill_rounds)
        t_tile += read_bytes / chip.sram_bw_per_core * spill_rounds
        used = 1
        for s in split:
            used *= s
        raw.append(ExecPlan(split, r, used, t_tile, cap, noc_total,
                            remote_per_core))
    plans = _pareto(raw, lambda p: p.time, lambda p: p.space)
    if len(plans) > max_plans:
        idxs = [int(i * (len(plans) - 1) / (max_plans - 1))
                for i in range(max_plans)]
        plans = [plans[i] for i in sorted(set(idxs))]
    return plans


def enumerate_preload_plans(op: Op, exec_plan: ExecPlan, chip: ChipConfig,
                            cost: AnalyticCostModel | None = None,
                            ) -> list[PreloadPlan]:
    """Pareto-optimal preload-state plans for an op whose execute-state plan
    is fixed (paper §4.3, Tradeoffs 2 and 3).  Sorted max-space first."""
    cost = cost or AnalyticCostModel(chip)
    split, used, r = exec_plan.split, exec_plan.cores_used, exec_plan.chunk

    shared = []   # (tile_bytes, group, resident_need_frac, q, hbm?)
    base_space = 0          # non-shared per-core preload bytes
    hbm_bytes = 0
    base_noc = 0
    for t in op.inputs:
        tb = t.tile_bytes(split)
        q = 1
        for dix in t.dims:
            q *= split[dix]
        g = used // max(q, 1)
        if t.from_hbm:
            hbm_bytes += t.bytes_total
        if g <= 1:
            base_space += tb
            if t.from_hbm:
                base_noc += t.bytes_total
            continue
        need = 1.0 if r == 1 else 1.0 / g
        shared.append((tb, g, need, q, t.from_hbm))

    fracs = {1.0}
    for _, g, _, _, _ in shared:
        f = 1.0
        while f > 1.0 / g:
            f /= 2
            fracs.add(max(f, 1.0 / g))
        fracs.add(1.0 / g)
    out = []
    for f in sorted(fracs, reverse=True):
        space = base_space
        noc_pre = base_noc
        dist_vol_per_core = 0
        noc_dist = 0
        for tb, g, need, q, from_hbm in shared:
            ff = max(f, 1.0 / g)
            space += int(tb * ff)
            if from_hbm:
                noc_pre += int(tb * ff * g) * q
            missing = max(0.0, need - ff)
            dist_vol_per_core += int(tb * missing)
            noc_dist += int(tb * missing) * used
        t_dist = cost.dist_time(dist_vol_per_core) if dist_vol_per_core else 0.0
        out.append(PreloadPlan(f, space, t_dist, noc_dist, noc_pre, hbm_bytes))
    return _pareto(out, lambda p: p.dist_time, lambda p: p.space)
