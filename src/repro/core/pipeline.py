"""Cached, incremental ELK compile pipeline (DESIGN.md §1-§2).

The compile path is an explicit pass sequence

    build graph -> curve cache -> candidate orders -> inductive schedule
                -> finalize -> select

driven by a per-compile :class:`CompileContext` that owns the shared state
the passes would otherwise re-derive from scratch:

* :class:`PlanCurveCache` — exec/preload Pareto curves interned by
  ``(op signature, chip)``.  Identical layers, repeated ``Scheduler``
  instances (the §6.1 baseline sweeps build ten per design), every
  candidate preload order, and both reduced-L extrapolation truncations
  all hit the same curve objects.
* :class:`WindowCache` — §4.3 allocation windows memoized on a frozen
  item-signature key (curve identities + fixed choices + capacity).  The
  greedy descent result is independent of the window's interconnect
  surcharge, so one solve serves every order/design that builds the same
  window.
* a process-level :class:`PlanCache` keyed by ``(model config, chip,
  batch, seq, phase, design, ...)`` so ``compare_designs``, the serving
  stack (``integration.pod_plan`` / ``serve.engine``), the dry-run driver
  and the benchmarks reuse finished :class:`ExecutionPlan` objects instead
  of recompiling per request.

Cached plans are shared objects — treat them as immutable.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional

from repro.chip.config import ChipConfig
from repro.core.cost_model import AnalyticCostModel
from repro.core.fusion import (FUSION_VERSION, FusedOp,
                               enumerate_fused_exec_plans, fuse_graph,
                               fusion_signature)
from repro.core.graph import OpGraph, Phase, build_graph
from repro.core.partition import (enumerate_exec_plans,
                                  enumerate_preload_plans,
                                  op_curve_signature)
from repro.core.plan import Breakdown, ExecutionPlan, Utilization
from repro.models.config import ModelConfig

PIPELINE_PASSES = ("graph", "curves", "orders", "schedule", "finalize",
                   "select")


def plan_signature(cfg: ModelConfig, chip: ChipConfig, *parts) -> tuple:
    """The canonical plan-cache key prefix: model + chip identity plus the
    chip's derived topology and memory-hierarchy signatures, followed by
    the caller's own discriminating parts.

    Every plan-level cache in the repo (``compile_pipeline``,
    ``plan_pipeline``, ``plan_hybrid``) builds its key through this one
    helper so a new hardware knob only has to be added here — forgetting
    to thread it through some key assembly elsewhere was exactly how a
    stale-hit bug could slip in.
    """
    return (cfg, chip, chip.topo_signature, chip.mem_signature) + parts


# ---------------------------------------------------------------------------
# pass 2 state: plan-curve cache
# ---------------------------------------------------------------------------

class PlanCurveCache:
    """Interns exec/preload Pareto curves per (op signature, chip).

    Every interned list gets a stable integer ``uid`` used by the window
    cache to build frozen item-signature keys without hashing plan
    contents.  Derived curves (execution-space-capped exec curves, the
    Static baseline's single-plan preload picks) are interned too, so two
    ``Scheduler`` instances with the same knobs share identical objects.
    """

    def __init__(self, chip: ChipConfig, cost: Optional[AnalyticCostModel] = None):
        self.chip = chip
        self.cost = cost or AnalyticCostModel(chip)
        # curves depend on topology through rotation/distribution costs
        # (and, for capped variants, on the memory hierarchy); the combined
        # hardware signature in every key makes a topology or tier change
        # miss even if a cache instance were ever shared across chips
        self._hw_sig = (chip.topo_signature, chip.mem_signature)
        self.hits = 0
        self.misses = 0
        self._exec: dict = {}        # sig -> [ExecPlan]
        self._pre: dict = {}         # (sig, exec key) -> [PreloadPlan]
        self._derived: dict = {}     # transform key -> list
        self._uids: dict = {}        # id(list) -> uid
        self._next_uid = 0

    def _intern(self, plans: list) -> list:
        self._uids[id(plans)] = self._next_uid
        self._next_uid += 1
        return plans

    def uid_of(self, plans) -> Optional[int]:
        return self._uids.get(id(plans))

    def exec_plans(self, op) -> list:
        # FusedOp signatures carry curve_signature_extra (incl. the fusion
        # version), so fused and plain curves can never share an entry
        sig = (op_curve_signature(op), self._hw_sig)
        got = self._exec.get(sig)
        if got is None:
            self.misses += 1
            enum = (enumerate_fused_exec_plans if isinstance(op, FusedOp)
                    else enumerate_exec_plans)
            got = self._exec[sig] = self._intern(
                enum(op, self.chip, self.cost))
        else:
            self.hits += 1
        return got

    def exec_plans_capped(self, op, cap: int) -> list:
        """The Static/capped baselines' single fastest-fitting plan."""
        sig = (op_curve_signature(op), self._hw_sig, "cap", cap)
        got = self._derived.get(sig)
        if got is None:
            self.misses += 1
            plans = self.exec_plans(op)
            fit = [p for p in plans if p.space <= cap]
            got = self._derived[sig] = self._intern(
                [min(fit or plans, key=lambda p: p.time)])
        else:
            self.hits += 1
        return got

    def preload_plans(self, op, exec_plan) -> list:
        sig = (op_curve_signature(op), self._hw_sig, exec_plan.key())
        got = self._pre.get(sig)
        if got is None:
            self.misses += 1
            got = self._pre[sig] = self._intern(
                enumerate_preload_plans(op, exec_plan, self.chip, self.cost))
        else:
            self.hits += 1
        return got

    def preload_plans_static(self, op, exec_plan, first: bool) -> list:
        """Static baseline: the max- or min-footprint plan only."""
        sig = (op_curve_signature(op), self._hw_sig, exec_plan.key(),
               "static", first)
        got = self._derived.get(sig)
        if got is None:
            self.misses += 1
            plans = self.preload_plans(op, exec_plan)
            got = self._derived[sig] = self._intern(
                [plans[0] if first else plans[-1]])
        else:
            self.hits += 1
        return got


# ---------------------------------------------------------------------------
# pass 4 state: window cache
# ---------------------------------------------------------------------------

class WindowCache:
    """Memoized §4.3 greedy window solves.

    Key: ``(capacity, ((curve uid, fixed, fixed_choice), ...))`` — the
    items' order matters (it is the greedy's tie-break order).  Value: the
    *core* of an allocation — ``(feasible, per-slot choices, space,
    exec_time, dist_time, exec_noc_bytes)`` — which is independent of the
    window's ``extra_preload_noc`` surcharge; callers finish the cost
    arithmetic per lookup.
    """

    def __init__(self):
        self._d: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, key):
        got = self._d.get(key)
        if got is not None:
            self.hits += 1
        return got

    def put(self, key, core) -> None:
        self.misses += 1
        self._d[key] = core


# ---------------------------------------------------------------------------
# compile context (one per compile / compare_designs sweep)
# ---------------------------------------------------------------------------

class CompileContext:
    """Shared state threaded through every pass of one compile.

    One context serves any number of ``Scheduler`` instances, designs and
    candidate orders, as long as they target the same chip.
    """

    def __init__(self, chip: ChipConfig,
                 cost: Optional[AnalyticCostModel] = None):
        self.chip = chip
        if cost is not None and getattr(cost, "chip", chip) != chip:
            raise ValueError("cost model bound to a different chip")
        self.cost = cost or AnalyticCostModel(chip)
        self.curves = PlanCurveCache(chip, self.cost)
        self.windows = WindowCache()
        self._graphs: dict = {}

    def graph(self, cfg: ModelConfig, *, batch: int, seq: int,
              phase: Phase) -> OpGraph:
        key = (cfg, batch, seq, phase)
        got = self._graphs.get(key)
        if got is None:
            got = self._graphs[key] = build_graph(cfg, batch=batch, seq=seq,
                                                  phase=phase)
        return got

    def fused_graph(self, cfg: ModelConfig, *, batch: int, seq: int,
                    phase: Phase) -> OpGraph:
        """The same graph after the §8 fusion pass (chip-gated on aggregate
        SRAM).  Returns the base graph object itself when nothing fuses."""
        key = (cfg, batch, seq, phase, "fused", FUSION_VERSION)
        got = self._graphs.get(key)
        if got is None:
            base = self.graph(cfg, batch=batch, seq=seq, phase=phase)
            got = self._graphs[key] = fuse_graph(base, self.chip)
        return got


# ---------------------------------------------------------------------------
# process-level plan cache
# ---------------------------------------------------------------------------

class PlanCache:
    """Bounded LRU of finished ExecutionPlans, safe for serving threads."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key):
        with self._lock:
            got = self._d.get(key)
            if got is not None:
                self._d.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return got

    def put(self, key, plan) -> None:
        with self._lock:
            self._d[key] = plan
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self.hits = self.misses = 0


_PLAN_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    return _PLAN_CACHE


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# compile driver
# ---------------------------------------------------------------------------

def compile_pipeline(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                     seq: int, phase: Phase = "decode",
                     design: str = "ELK-Full", max_exact_ops: int = 400,
                     max_orders: int = 24,
                     ctx: Optional[CompileContext] = None,
                     cache: bool = True,
                     parallel: Optional[int] = None,
                     fusion: bool = False) -> ExecutionPlan:
    """Run the full pass pipeline for one (model, chip, shape, design).

    ``ctx`` shares curve/window caches across calls (``compare_designs``
    passes one context for all five designs); ``cache=True`` additionally
    consults the process-level plan cache.  ``parallel`` evaluates §4.4
    candidate preload orders on a worker pool of that size.

    ``fusion=True`` additionally compiles the §8 fused graph against the
    same context and returns whichever plan is faster — fusion is applied
    only where the scheduler's fused curves actually beat preload overlap,
    and the result is never worse than the fusion-off plan.  The fusion
    signature joins every plan-cache key (like ``topo_signature``), so the
    two knob settings can never serve each other's entries.
    """
    if ctx is not None and type(ctx.cost) is not AnalyticCostModel:
        # plan-cache keys don't encode the cost model; a context with a
        # custom one must not poison (or read) default-cost entries
        cache = False
    key = plan_signature(cfg, chip, fusion_signature(fusion), batch, seq,
                         phase, design, max_exact_ops, max_orders)
    if cache:
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            return hit
    ctx = ctx or CompileContext(chip)
    plan = _compile_variant(cfg, chip, batch, seq, phase, design,
                            max_exact_ops, max_orders, ctx, cache, parallel,
                            fused=False)
    if fusion:
        fgraph = ctx.fused_graph(cfg, batch=batch, seq=seq, phase=phase)
        base_graph = ctx.graph(cfg, batch=batch, seq=seq, phase=phase)
        fplan = None
        if fgraph is not base_graph:
            fplan = _compile_variant(cfg, chip, batch, seq, phase, design,
                                     max_exact_ops, max_orders, ctx, cache,
                                     parallel, fused=True)
        if fplan is not None and fplan.total_time < plan.total_time:
            plan = dataclasses.replace(fplan, fusion=True)
        else:
            # base graph won (or nothing fused): return a distinct object so
            # the fusion-on cache entry never aliases the fusion-off one
            plan = dataclasses.replace(plan, fusion=False)
    if cache:
        _PLAN_CACHE.put(key, plan)
    return plan


def _compile_variant(cfg, chip, batch, seq, phase, design, max_exact_ops,
                     max_orders, ctx, cache, parallel,
                     fused: bool) -> ExecutionPlan:
    graph = (ctx.fused_graph(cfg, batch=batch, seq=seq, phase=phase) if fused
             else ctx.graph(cfg, batch=batch, seq=seq, phase=phase))
    if len(graph.ops) <= max_exact_ops:
        return _exact_plan(cfg, chip, batch, seq, phase, design, max_orders,
                           ctx, cache, parallel, fused)
    plan = _extrapolated(cfg, chip, batch, seq, phase, design, max_orders,
                         ctx, cache, parallel, fused)
    if design in ("ELK-Dyn", "ELK-Full"):
        # ELK's search space contains every static configuration; linear
        # layer-extrapolation is not monotonicity-preserving across
        # designs, so re-impose dominance at the extrapolated level.
        st = _extrapolated(cfg, chip, batch, seq, phase, "Static",
                           max_orders, ctx, cache, parallel, fused)
        if st.total_time < plan.total_time:
            plan = dataclasses.replace(st, design=design)
    return plan


def _exact_plan(cfg, chip, batch, seq, phase, design, max_orders, ctx,
                cache, parallel, fused: bool = False) -> ExecutionPlan:
    key = plan_signature(cfg, chip, fusion_signature(fused), batch, seq,
                         phase, design, "exact", max_orders)
    if cache:
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            return hit
    from repro.core.baselines import build_plan
    graph = (ctx.fused_graph(cfg, batch=batch, seq=seq, phase=phase) if fused
             else ctx.graph(cfg, batch=batch, seq=seq, phase=phase))
    plan = build_plan(graph, chip, design, max_orders=max_orders, ctx=ctx,
                      parallel=parallel)
    if cache:
        _PLAN_CACHE.put(key, plan)
    return plan


def _layer_counts(cfg: ModelConfig) -> tuple[int, int]:
    period = max(cfg.moe_every, 1) if cfg.moe_experts else 1
    l1 = cfg.moe_first_dense + 3 * period
    l2 = l1 + 2 * period
    if l2 >= cfg.num_layers:
        return cfg.num_layers, cfg.num_layers
    return l1, l2


def _extrapolated(cfg, chip, batch, seq, phase, design, max_orders, ctx,
                  cache, parallel, fused: bool = False) -> ExecutionPlan:
    """Reduced-L schedule + linear extrapolation in the layer count.

    The two truncations share every curve (identical layer signatures) and
    most allocation windows through ``ctx``, and land in the plan cache so
    the §6.1 dominance re-check and ``compare_designs`` reuse them.
    """
    l1, l2 = _layer_counts(cfg)
    cfg1 = dataclasses.replace(cfg, num_layers=l1)
    cfg2 = dataclasses.replace(cfg, num_layers=l2)
    # byte/flop totals are fusion-invariant (a FusedOp sums its parts), so
    # the base graph serves both variants' utilization arithmetic
    g_full = ctx.graph(cfg, batch=batch, seq=seq, phase=phase)
    p1 = _exact_plan(cfg1, chip, batch, seq, phase, design, max_orders, ctx,
                     cache, parallel, fused)
    p2 = _exact_plan(cfg2, chip, batch, seq, phase, design, max_orders, ctx,
                     cache, parallel, fused)
    if l1 == l2:
        return p2

    scale = (cfg.num_layers - l2) / (l2 - l1)

    def ext(a: float, b: float) -> float:
        return max(b + (b - a) * scale, 0.0)

    total = ext(p1.total_time, p2.total_time)
    breakdown = Breakdown(
        preload_only=ext(p1.breakdown.preload_only, p2.breakdown.preload_only),
        execute_only=ext(p1.breakdown.execute_only, p2.breakdown.execute_only),
        overlapped=ext(p1.breakdown.overlapped, p2.breakdown.overlapped),
        interconnect_stall=ext(p1.breakdown.interconnect_stall,
                               p2.breakdown.interconnect_stall),
    )
    # extrapolate resource byte/flop totals, recompute utilizations
    flops = sum(op.flops for op in g_full.ops)
    hbm_bytes = sum(op.hbm_bytes for op in g_full.ops)

    def occ_of(p: ExecutionPlan) -> float:
        return p.util.interconnect * p.total_time

    noc_occ = ext(occ_of(p1), occ_of(p2))
    util = Utilization(
        hbm=min(hbm_bytes / (chip.hbm_bw * total), 1.0) if chip.hbm_bw else 0.0,
        interconnect=min(noc_occ / total, 1.0),
        flops=min(flops / (chip.total_flops * total), 1.0),
        achieved_tflops=flops / total / 1e12,
    )
    return ExecutionPlan(p2.graph, chip.name, design, p2.decisions,
                         p2.preload_order, p2.timing, total, breakdown, util,
                         extrapolated_from_layers=l2)
