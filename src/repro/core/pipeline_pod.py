"""Pipeline-parallel pod planner (DESIGN.md §7).

The compile stack so far plans one operator graph onto one flat ICCA chip;
the pod flavors (``hier_pod``, the IPU-POD4 emulator target) were only a
contention model.  This module partitions the graph into **pipeline stages
across the chips of a pod**:

* the layer stack is cut at decoder-layer boundaries into ``S`` contiguous
  stages, one per member chip (``chip_view()`` projects the pod topology
  onto one chip's intra-chip link classes);
* each stage's sub-graph is scheduled with the unmodified inductive
  :class:`~repro.core.scheduler.Scheduler` through **one shared**
  :class:`~repro.core.pipeline.CompileContext` — identical layers across
  stages hit the same Pareto-curve and allocation-window caches, and stage
  sub-graph signatures key a per-search plan memo;
* the cut points come from a **DP over layer boundaries** minimizing the
  steady-state bottleneck ``max_s(stage interval + inter-stage activation
  transfer on the inter-chip tier)``;
* the result is a :class:`PipelinePlan`: per-stage ``ExecutionPlan``s, the
  steady-state interval, fill/drain and microbatch knobs.

Steady-state interval
---------------------
A pipelined stage serves a stream of *independent* microbatches (distinct
request groups under continuous batching), so consecutive microbatches
software-pipeline on the chip: microbatch ``m+1``'s preloads overlap
microbatch ``m``'s execution.  The stage's steady-state interval is
therefore the bottleneck *serial resource* of its plan — the HBM/delivery
chain (§4.5: preloads are served sequentially) or the execution chain —
not the plan's end-to-end latency, which pays the fill ramp every pass.
The replicated baseline (one full-model plan per chip) cannot hide that
ramp: decode step ``t+1`` of the *same* requests needs step ``t``'s
sampled token, so each step pays the plan's full ``total_time``.  That
fill/stall amortization is exactly what the pipeline buys; both sides of
the comparison stream identical HBM bytes per token.

Degenerate case: one stage (or a one-chip pod) returns today's single-chip
plan unchanged — bit-identical, test-pinned.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.chip.config import ChipConfig
from repro.chip.topology import ChipView
from repro.core.graph import OpGraph, Phase, build_graph
from repro.core.partition import op_curve_signature
from repro.core.pipeline import CompileContext, PlanCache
from repro.core.plan import ExecutionPlan
from repro.models.config import ModelConfig

_INF = math.inf


# ---------------------------------------------------------------------------
# plan artifacts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage: a contiguous layer range on one member chip."""
    index: int
    layers: tuple[int, int]        # [lo, hi) decoder-layer range
    graph: OpGraph                 # exact stage sub-graph (conservation)
    plan: ExecutionPlan            # per-microbatch schedule (may extrapolate)
    time: float                    # per-microbatch stage latency
    interval: float                # steady-state per-microbatch interval
    send_bytes: int                # activation bytes to the next stage
    send_time: float               # inter-chip-tier transfer estimate


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A graph partitioned into pipeline stages across a pod."""
    model: str
    phase: Phase
    chip_name: str
    design: str
    num_chips: int
    batch: int                     # total in-flight requests
    microbatch: int                # requests per microbatch
    microbatches: int              # concurrent microbatch groups (>= stages)
    stages: tuple[StagePlan, ...]
    interval: float                # steady-state per-microbatch bottleneck
    batch_interval: float          # microbatches * interval: one decode
    #                                round of the whole running batch
    fill_time: float               # first microbatch end-to-end latency
    total_time: float              # fill + (microbatches-1) * interval

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def total_flops(self) -> float:
        """Per-microbatch FLOPs over all stage sub-graphs (conserved across
        cuts — fuzz-tested against the unpartitioned graph)."""
        return sum(op.flops for st in self.stages for op in st.graph.ops)

    @property
    def hbm_bytes(self) -> int:
        """Per-microbatch off-chip bytes over all stage sub-graphs."""
        return sum(op.hbm_bytes for st in self.stages for op in st.graph.ops)


# ---------------------------------------------------------------------------
# graph slicing
# ---------------------------------------------------------------------------

def _layer_starts(g: OpGraph) -> tuple[dict[int, int], int, int]:
    """First op index per decoder layer + the [first, end) span of all
    layer ops (ops outside it are the prefix/suffix: embed, encoder,
    final norm, lm_head)."""
    starts: dict[int, int] = {}
    first = len(g.ops)
    last_end = 0
    for i, op in enumerate(g.ops):
        if op.layer >= 0:
            first = min(first, i)
            last_end = max(last_end, i + 1)
            if op.layer not in starts:
                starts[op.layer] = i
    return starts, first, last_end


def stage_subgraph(g: OpGraph, lo: int, hi: int, num_layers: int) -> OpGraph:
    """The sub-graph of decoder layers [lo, hi); stage 0 keeps the prefix
    ops (embed/frontends/encoder), the last stage keeps the suffix
    (final norm, lm_head).  ``preload_dep`` indices are re-based; deps are
    intra-layer (MoE router -> experts), so cuts at layer boundaries never
    sever one."""
    starts, first, last_end = _layer_starts(g)
    off = starts[lo] if lo > 0 else 0
    end = starts[hi] if hi < num_layers else len(g.ops)
    sub = []
    for op in g.ops[off:end]:
        dep = op.preload_dep
        if dep >= 0:
            dep -= off
            if dep < 0:            # severed dep (never at layer cuts)
                dep = -1
            op = dataclasses.replace(op, preload_dep=dep)
        sub.append(op)
    span_lo = starts[lo] - off
    span_hi = (starts[lo + 1] - off) if lo + 1 < hi else \
        (min(last_end, end) - off)
    return OpGraph(f"{g.model}[{lo}:{hi}]", g.phase, tuple(sub),
                   (span_lo, span_hi), hi - lo)


# ---------------------------------------------------------------------------
# steady-state interval of one stage plan
# ---------------------------------------------------------------------------

def steady_interval(plan: ExecutionPlan, chip: ChipConfig,
                    ctx: Optional[CompileContext] = None) -> float:
    """Throughput bound of a stage serving back-to-back microbatches: the
    busier of the serial HBM/delivery chain (§4.5 rule 2) and the serial
    execution chain, clamped to the plan's one-pass latency."""
    cost = ctx.cost if ctx is not None else None
    pre_bw = chip.preload_noc_bw
    hbm = 0.0
    for d in plan.decisions:
        p = d.preload_plan
        if p is None or not (p.hbm_bytes or p.noc_preload_bytes):
            continue
        if cost is not None:
            t_hbm = cost.hbm_time(p.hbm_bytes)
        else:
            t_hbm = (p.hbm_bytes / chip.hbm_bw + chip.hbm_latency) \
                if chip.hbm_bw else 0.0
        hbm += max(t_hbm, p.noc_preload_bytes / pre_bw)
    exe = sum(t.t_e_exe - t.t_s_exe for t in plan.timing)
    if plan.total_time <= 0:
        return max(hbm, exe)
    return min(max(hbm, exe), plan.total_time)


# ---------------------------------------------------------------------------
# stage-cost search state
# ---------------------------------------------------------------------------

class _StageCosts:
    """Memoized stage compiles for the cut DP.

    Stage plans are keyed by the sub-graph's op-signature tuple (identical
    layer stacks collapse every same-shape candidate range to one compile),
    and every compile shares one ``CompileContext`` — curves and allocation
    windows are computed once for the whole search.
    """

    def __init__(self, g: OpGraph, member: ChipConfig, design: str,
                 max_orders: int, max_exact_ops: int):
        self.g = g
        self.member = member
        self.design = design
        self.max_orders = max_orders
        self.max_exact_ops = max_exact_ops
        self.ctx = CompileContext(member)
        self.num_layers = g.num_layers
        self._sigs = [op_curve_signature(op) for op in g.ops]
        starts, first, last_end = _layer_starts(g)
        self._starts, self._first, self._last_end = starts, first, last_end
        # layer uniformity: identical per-layer signatures let deep stages
        # extrapolate from truncations (MoE stacks with dense prefixes are
        # not uniform and always schedule exactly)
        sig0 = self._layer_sig(0)
        self.uniform = all(self._layer_sig(i) == sig0
                           for i in range(1, g.num_layers))
        self._memo: dict = {}

    def _layer_sig(self, i: int) -> tuple:
        lo = self._starts[i]
        hi = self._starts[i + 1] if i + 1 < self.num_layers else self._last_end
        return tuple(self._sigs[lo:hi])

    def _compile(self, sub: OpGraph) -> ExecutionPlan:
        from repro.core.baselines import build_plan
        return build_plan(sub, self.member, self.design,
                          max_orders=self.max_orders, ctx=self.ctx)

    def stage(self, lo: int, hi: int) -> tuple[OpGraph, ExecutionPlan,
                                               float, float]:
        """(sub-graph, plan, per-microbatch time, steady interval) for
        decoder layers [lo, hi)."""
        sub = stage_subgraph(self.g, lo, hi, self.num_layers)
        key = (lo == 0, hi == self.num_layers,
               tuple(self._sigs[self._op_lo(lo):self._op_hi(hi)]))
        got = self._memo.get(key)
        if got is None:
            got = self._solve(sub, lo, hi)
            self._memo[key] = got
        plan, time, ival = got
        return sub, plan, time, ival

    def _op_lo(self, lo: int) -> int:
        return self._starts[lo] if lo > 0 else 0

    def _op_hi(self, hi: int) -> int:
        return self._starts[hi] if hi < self.num_layers else len(self.g.ops)

    def _solve(self, sub: OpGraph, lo: int, hi: int):
        k = hi - lo
        if len(sub.ops) <= self.max_exact_ops or not self.uniform or k <= 3:
            plan = self._compile(sub)
            return plan, plan.total_time, steady_interval(
                plan, self.member, self.ctx)
        # deep uniform stage: linear layer-count extrapolation from two
        # truncations of the same flavor (both land in the memo, so every
        # deep candidate range reuses them)
        k2 = min(k - 1, 8)
        k1 = max(k2 - 2, 1)
        scale = (k - k2) / (k2 - k1)

        def probe(kk: int):
            # anchor the truncation to whichever end carries this stage's
            # prefix/suffix ops, so embed and lm_head stay in both probes
            if hi == self.num_layers and lo > 0:
                s = stage_subgraph(self.g, hi - kk, hi, self.num_layers)
            else:
                s = stage_subgraph(self.g, lo, lo + kk, self.num_layers)
            p = self._compile(s)
            return p, p.total_time, steady_interval(p, self.member, self.ctx)

        p1, t1, i1 = probe(k1)
        p2, t2, i2 = probe(k2)
        time = max(t2 + (t2 - t1) * scale, 0.0)
        ival = max(i2 + (i2 - i1) * scale, 0.0)
        plan = dataclasses.replace(p2, total_time=time,
                                   extrapolated_from_layers=k2)
        return plan, time, min(ival, time)


# ---------------------------------------------------------------------------
# cut-point DP
# ---------------------------------------------------------------------------

def _cut_dp(costs: _StageCosts, num_stages: int, send_time: float,
            slack: Optional[int]) -> list[int]:
    """Cut points minimizing ``max_s(interval_s + send_s)`` (ties broken by
    total fill).  ``slack`` bands candidate stage depths around the
    balanced ``ceil(L/S)`` to bound the number of stage compiles; the band
    widens automatically if it admits no feasible partition."""
    L, S = costs.num_layers, num_stages
    base = -(-L // S)
    if slack is None:
        slack = L if L <= 16 else max(3, base // 3)

    def run(band: int) -> Optional[list[int]]:
        lo_k = max(1, base - band)
        hi_k = min(L, base + band)

        def stage_cost(a: int, b: int) -> float:
            if not (lo_k <= b - a <= hi_k):
                return _INF
            _, _, _, ival = costs.stage(a, b)
            return ival + (send_time if b < L else 0.0)

        # f[s][l]: min bottleneck over first l layers in s stages
        f = [[_INF] * (L + 1) for _ in range(S + 1)]
        g = [[0.0] * (L + 1) for _ in range(S + 1)]    # fill tie-break
        back = [[-1] * (L + 1) for _ in range(S + 1)]
        f[0][0] = 0.0
        for s in range(1, S + 1):
            for l in range(s, L - (S - s) + 1):
                for m in range(s - 1, l):
                    if f[s - 1][m] == _INF:
                        continue
                    if not (lo_k <= l - m <= hi_k):
                        continue
                    c = stage_cost(m, l)
                    if c == _INF:
                        continue
                    v = max(f[s - 1][m], c)
                    fill = g[s - 1][m] + costs.stage(m, l)[2]
                    if v < f[s][l] - 1e-15 or (
                            abs(v - f[s][l]) <= 1e-15 and fill < g[s][l]):
                        f[s][l], g[s][l], back[s][l] = v, fill, m
        if f[S][L] == _INF:
            return None
        cuts, l = [], L
        for s in range(S, 0, -1):
            cuts.append(l)
            l = back[s][l]
        return list(reversed(cuts))        # S cut points, last == L

    band = slack
    while True:
        cuts = run(band)
        if cuts is not None:
            return cuts
        if band >= L:
            raise RuntimeError(f"no feasible {S}-stage cut of {L} layers")
        band = min(L, max(band * 2, 1))


# ---------------------------------------------------------------------------
# planner entry
# ---------------------------------------------------------------------------

_PIPE_CACHE = PlanCache(maxsize=64)


def pipeline_cache() -> PlanCache:
    return _PIPE_CACHE


def plan_pipeline(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                  seq: int, phase: Phase = "decode",
                  design: str = "ELK-Full",
                  num_stages: Optional[int] = None,
                  microbatches: Optional[int] = None,
                  max_orders: int = 4, max_exact_ops: int = 400,
                  cut_slack: Optional[int] = None,
                  cache: bool = True) -> PipelinePlan:
    """Partition ``cfg``'s operator graph into pipeline stages across the
    chips of ``chip`` (a pod config: ``num_chips >= 1``).

    ``num_stages`` defaults to the pod's chip count; ``microbatches``
    defaults to the stage count (the minimum keeping every stage busy in
    steady state).  The per-microbatch request count is
    ``ceil(batch / microbatches)``.

    A one-stage (or one-chip) plan degenerates to today's single-chip
    compile path, bit-identical (test-pinned).
    """
    S = num_stages if num_stages is not None else max(chip.num_chips, 1)
    S = max(1, min(S, max(chip.num_chips, 1), cfg.num_layers))
    M = microbatches if microbatches is not None else S
    M = max(M, S)
    key = (cfg, chip, chip.topo_signature, batch, seq, phase, design, S, M,
           max_orders, max_exact_ops)
    if cache:
        hit = _PIPE_CACHE.get(key)
        if hit is not None:
            return hit

    from repro.core.elk import compile_model

    if S == 1:
        plan = compile_model(cfg, chip, batch=batch, seq=seq, phase=phase,
                             design=design, max_orders=max_orders)
        g = build_graph(cfg, batch=batch, seq=seq, phase=phase)
        st = StagePlan(0, (0, cfg.num_layers), g, plan, plan.total_time,
                       plan.total_time, 0, 0.0)
        pp = PipelinePlan(cfg.name, phase, chip.name, design,
                          max(chip.num_chips, 1), batch, batch, 1, (st,),
                          plan.total_time, plan.total_time, plan.total_time,
                          plan.total_time)
        if cache:
            _PIPE_CACHE.put(key, pp)
        return pp

    b = -(-batch // M)
    view: ChipView = chip.chip_view()
    g = build_graph(cfg, batch=b, seq=seq, phase=phase)
    costs = _StageCosts(g, view.chip, design, max_orders, max_exact_ops)

    starts, first, last_end = _layer_starts(g)
    # activation crossing a layer boundary: the last op of the previous
    # layer's output (rows x d_model for every supported family)
    act_bytes = g.ops[(starts[1] if cfg.num_layers > 1 else last_end) - 1] \
        .out_bytes
    send_time = act_bytes / view.inter_bw + view.inter_latency

    cuts = _cut_dp(costs, S, send_time, cut_slack)
    stages = []
    lo = 0
    for i, hi in enumerate(cuts):
        sub, plan, time, ival = costs.stage(lo, hi)
        send_b = act_bytes if hi < cfg.num_layers else 0
        send_t = send_time if hi < cfg.num_layers else 0.0
        stages.append(StagePlan(i, (lo, hi), sub, plan, time, ival,
                                send_b, send_t))
        lo = hi
    interval = max(st.interval + st.send_time for st in stages)
    fill = sum(st.time + st.send_time for st in stages)
    pp = PipelinePlan(cfg.name, phase, chip.name, design,
                      max(chip.num_chips, 1), b * M, b, M, tuple(stages),
                      interval, M * interval, fill,
                      fill + (M - 1) * interval)
    if cache:
        _PIPE_CACHE.put(key, pp)
    return pp


def replicated_plan(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                    seq: int, phase: Phase = "decode",
                    design: str = "ELK-Full",
                    max_orders: int = 4) -> ExecutionPlan:
    """Data-parallel baseline: every member chip replicates the full model
    and serves ``batch / num_chips`` requests.  Its steady-state interval
    per pod decode round is the member plan's ``total_time`` — step ``t+1``
    of the same requests cannot start before step ``t``'s sampled tokens,
    so the per-step fill/stall is paid every round."""
    from repro.core.elk import compile_model
    view = chip.chip_view()
    b = -(-batch // max(chip.num_chips, 1))
    return compile_model(cfg, view.chip, batch=b, seq=seq, phase=phase,
                         design=design, max_orders=max_orders)
