"""Pipeline-parallel pod planner (DESIGN.md §7).

The compile stack so far plans one operator graph onto one flat ICCA chip;
the pod flavors (``hier_pod``, the IPU-POD4 emulator target) were only a
contention model.  This module partitions the graph into **pipeline stages
across the chips of a pod**:

* the layer stack is cut at decoder-layer boundaries into ``S`` contiguous
  stages, one per member chip (``chip_view()`` projects the pod topology
  onto one chip's intra-chip link classes);
* each stage's sub-graph is scheduled with the unmodified inductive
  :class:`~repro.core.scheduler.Scheduler` through **one shared**
  :class:`~repro.core.pipeline.CompileContext` — identical layers across
  stages hit the same Pareto-curve and allocation-window caches, and stage
  sub-graph signatures key a per-search plan memo;
* the cut points come from a **DP over layer boundaries** minimizing the
  steady-state bottleneck ``max_s(stage interval + inter-stage activation
  transfer on the inter-chip tier)``;
* the result is a :class:`PipelinePlan`: per-stage ``ExecutionPlan``s, the
  steady-state interval, fill/drain and microbatch knobs.

Steady-state interval
---------------------
A pipelined stage serves a stream of *independent* microbatches (distinct
request groups under continuous batching), so consecutive microbatches
software-pipeline on the chip: microbatch ``m+1``'s preloads overlap
microbatch ``m``'s execution.  The stage's steady-state interval is
therefore the bottleneck *serial resource* of its plan — the HBM/delivery
chain (§4.5: preloads are served sequentially) or the execution chain —
not the plan's end-to-end latency, which pays the fill ramp every pass.
The replicated baseline (one full-model plan per chip) cannot hide that
ramp: decode step ``t+1`` of the *same* requests needs step ``t``'s
sampled token, so each step pays the plan's full ``total_time``.  That
fill/stall amortization is exactly what the pipeline buys; both sides of
the comparison stream identical HBM bytes per token.

Degenerate case: one stage (or a one-chip pod) returns today's single-chip
plan unchanged — bit-identical, test-pinned.

Hybrid parallelism (DESIGN.md §9)
---------------------------------
:func:`plan_hybrid` generalizes the cut DP to a joint search over (cut,
tensor-parallel width, data-parallel replicas, microbatch count): a stage
may span ``width`` chips (its sub-graph sharded Megatron-style by
:func:`shard_graph` — weight/KV bytes divided, per-layer all-reduce for
row-sharded matmuls, expert all-to-all for MoE — priced through
``TopologyModel.collective_time``) and/or be replicated ``replicas`` times
(round-robin over the microbatch stream divides the effective cadence).
Fewer, wider stages stream each weight byte fewer times per decode round,
which is where hybrid beats the pure pipeline on HBM-bound decode; the
pure-pipeline plan is always computed alongside and returned whenever it
is at least as good, so ``mode="hybrid"`` is never worse and degenerates
bit-identically when widths/replicas are pinned to 1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.chip.config import ChipConfig
from repro.chip.topology import ChipView
from repro.core.graph import OpGraph, Phase, build_graph
from repro.core.partition import op_curve_signature
from repro.core.pipeline import CompileContext, PlanCache, plan_signature
from repro.core.plan import ExecutionPlan
from repro.models.config import ModelConfig

_INF = math.inf


# ---------------------------------------------------------------------------
# plan artifacts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage: a contiguous layer range on one stage group of
    ``width * replicas`` member chips (one chip in the pure pipeline)."""
    index: int
    layers: tuple[int, int]        # [lo, hi) decoder-layer range
    graph: OpGraph                 # exact stage sub-graph (conservation;
    #                                the sharded per-chip graph when width>1)
    plan: ExecutionPlan            # per-microbatch schedule (may extrapolate)
    time: float                    # per-microbatch stage latency
    interval: float                # steady-state per-microbatch interval
    send_bytes: int                # activation bytes to the next stage
    send_time: float               # inter-chip-tier transfer estimate
    # hybrid parallelism (DESIGN.md §9); defaults are the pure pipeline
    width: int = 1                 # tensor-parallel chips in this stage
    replicas: int = 1              # data-parallel copies of this stage
    collective_time: float = 0.0   # per-microbatch intra-stage collectives
    collectives: tuple = ()        # (kind, payload bytes) descriptors

    @property
    def chips(self) -> int:
        return self.width * self.replicas

    @property
    def effective_interval(self) -> float:
        """Steady per-microbatch cadence this stage group sustains:
        ``replicas`` copies round-robin the microbatch stream, each paying
        the sharded interval plus the intra-stage collectives, and the
        handoff to the next stage rides on top.  Bit-identical to
        ``interval + send_time`` in the degenerate width=replicas=1 case."""
        return (self.interval + self.collective_time) \
            / max(self.replicas, 1) + self.send_time


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A graph partitioned into pipeline stages across a pod."""
    model: str
    phase: Phase
    chip_name: str
    design: str
    num_chips: int
    batch: int                     # total in-flight requests
    microbatch: int                # requests per microbatch
    microbatches: int              # concurrent microbatch groups (>= stages)
    stages: tuple[StagePlan, ...]
    interval: float                # steady-state per-microbatch bottleneck
    batch_interval: float          # microbatches * interval: one decode
    #                                round of the whole running batch
    fill_time: float               # first microbatch end-to-end latency
    total_time: float              # fill + (microbatches-1) * interval

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def total_flops(self) -> float:
        """Per-microbatch FLOPs over all stage sub-graphs (conserved across
        cuts — fuzz-tested against the unpartitioned graph)."""
        return sum(op.flops for st in self.stages for op in st.graph.ops)

    @property
    def hbm_bytes(self) -> int:
        """Per-microbatch off-chip bytes over all stage sub-graphs."""
        return sum(op.hbm_bytes for st in self.stages for op in st.graph.ops)


# ---------------------------------------------------------------------------
# graph slicing
# ---------------------------------------------------------------------------

def _layer_starts(g: OpGraph) -> tuple[dict[int, int], int, int]:
    """First op index per decoder layer + the [first, end) span of all
    layer ops (ops outside it are the prefix/suffix: embed, encoder,
    final norm, lm_head)."""
    starts: dict[int, int] = {}
    first = len(g.ops)
    last_end = 0
    for i, op in enumerate(g.ops):
        if op.layer >= 0:
            first = min(first, i)
            last_end = max(last_end, i + 1)
            if op.layer not in starts:
                starts[op.layer] = i
    return starts, first, last_end


def stage_subgraph(g: OpGraph, lo: int, hi: int, num_layers: int) -> OpGraph:
    """The sub-graph of decoder layers [lo, hi); stage 0 keeps the prefix
    ops (embed/frontends/encoder), the last stage keeps the suffix
    (final norm, lm_head).  ``preload_dep`` indices are re-based; deps are
    intra-layer (MoE router -> experts), so cuts at layer boundaries never
    sever one."""
    starts, first, last_end = _layer_starts(g)
    off = starts[lo] if lo > 0 else 0
    end = starts[hi] if hi < num_layers else len(g.ops)
    sub = []
    for op in g.ops[off:end]:
        dep = op.preload_dep
        if dep >= 0:
            dep -= off
            if dep < 0:            # severed dep (never at layer cuts)
                dep = -1
            op = dataclasses.replace(op, preload_dep=dep)
        sub.append(op)
    span_lo = starts[lo] - off
    span_hi = (starts[lo + 1] - off) if lo + 1 < hi else \
        (min(last_end, end) - off)
    return OpGraph(f"{g.model}[{lo}:{hi}]", g.phase, tuple(sub),
                   (span_lo, span_hi), hi - lo)


# ---------------------------------------------------------------------------
# tensor-parallel graph sharding (DESIGN.md §9)
# ---------------------------------------------------------------------------

# Megatron-style shard rules by op-name suffix: (sharded iteration dim,
# divide-all-inputs).  Column-sharded projections split the output features
# (dim 1) — weight and bias divide, activations replicate, no collective.
# Row-sharded projections split the reduce dim (dim 2) — weight and
# activation divide, partial outputs need an all-reduce (detected below via
# ``dim in reduce_dims``).  Attention BMMs and the vector ops that ride a
# sharded intermediate (rope/softmax/activations/recurrences) split with
# the heads/features they follow; vector ops declare their intermediate as
# spanning only dim 0, so they divide every input explicitly.
_SHARD_RULES: dict[str, tuple[int, bool]] = {
    # column-parallel matmuls (QKV/head projections, up-projections)
    **{s: (1, False) for s in ("q", "kv", "qkv", "xq", "fc1", "gate_up",
                               "shared_up", "cm_k", "ssm_in",
                               "r", "k", "v", "g")},
    # row-parallel matmuls (output/down projections -> all-reduce)
    **{s: (2, False) for s in ("o", "xo", "out", "fc2", "down",
                               "shared_down", "cm_v", "ssm_out")},
    # head-sharded attention BMMs (merge happens in the o-proj all-reduce)
    **{s: (0, False) for s in ("score", "attnv", "xscore", "xattnv")},
    # vector ops on a head/feature-sharded intermediate
    **{s: (1, True) for s in ("rope", "act", "cm_act", "shared_act",
                              "wkv", "ssm_scan")},
    **{s: (0, True) for s in ("softmax", "xsoftmax")},
}
# replicated: ln*/router/embed/final_norm/lm_head/vision_patches — cheap,
# and their inputs arrive replicated after the preceding all-reduce.


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def shard_graph(g: OpGraph, width: int) -> tuple[OpGraph, tuple]:
    """Project a stage graph onto one of ``width`` tensor-parallel chips.

    Returns ``(per-chip graph, collective descriptors)`` where each
    descriptor is ``(kind, payload bytes)`` of one per-microbatch
    intra-stage collective: an all-reduce of the full output for every
    row-sharded matmul, and a dispatch + combine all-to-all pair for every
    expert-parallel MoE op (each chip owns ``1/width`` of the routed
    experts).  Op count, order and ``preload_dep`` indices are preserved,
    so MoE late binding survives sharding unchanged.
    """
    if width <= 1:
        return g, ()
    ops = []
    colls = []
    for op in g.ops:
        names = [t.name for t in op.inputs]
        if "w_experts" in names:
            # expert parallelism: split the routed rows/experts (dim 0),
            # ring the activations to the owning chips and back
            ops.append(_shard_op(op, 0, width, all_inputs=True))
            colls.append(("all_to_all", op.inputs[0].bytes_total))
            colls.append(("all_to_all", op.out_bytes))
            continue
        rule = _SHARD_RULES.get(op.name.rsplit(".", 1)[-1])
        if rule is None or len(op.dims) <= rule[0]:
            ops.append(op)             # replicated
            continue
        dim, all_inputs = rule
        ops.append(_shard_op(op, dim, width, all_inputs=all_inputs))
        if dim in op.reduce_dims:
            colls.append(("all_reduce", op.out_bytes))
    return OpGraph(f"{g.model}@tp{width}", g.phase, tuple(ops),
                   g.layer_span, g.num_layers), tuple(colls)


def _shard_op(op, dim: int, width: int, *, all_inputs: bool):
    """One op's ``1/width`` shard along iteration dim ``dim``: the dim,
    FLOPs and every tensor spanning it divide by the real shrink factor
    (ceil on the dim, so tiny dims never vanish); the output divides unless
    ``dim`` is reduced (row-shard -> full-size partial sums)."""
    old = op.dims[dim]
    new = _ceil_div(old, width)
    f = new / old
    dims = op.dims[:dim] + (new,) + op.dims[dim + 1:]
    inputs = tuple(
        dataclasses.replace(t, bytes_total=int(math.ceil(t.bytes_total * f)))
        if (all_inputs or dim in t.dims) else t
        for t in op.inputs)
    out = op.out_bytes if dim in op.reduce_dims \
        else int(math.ceil(op.out_bytes * f))
    return dataclasses.replace(op, dims=dims, flops=op.flops * f,
                               inputs=inputs, out_bytes=out)


# ---------------------------------------------------------------------------
# steady-state interval of one stage plan
# ---------------------------------------------------------------------------

def steady_interval(plan: ExecutionPlan, chip: ChipConfig,
                    ctx: Optional[CompileContext] = None) -> float:
    """Throughput bound of a stage serving back-to-back microbatches: the
    busiest of the per-tier serial preload chains (§4.5 rule 2 — each
    source tier's controllers serve sequentially), the shared delivery-NoC
    chain, and the serial execution chain, clamped to the plan's one-pass
    latency.  On a two-tier chip the single hbm chain makes this exactly
    the pre-§10 ``sum(max(t_hbm, t_noc))`` bound."""
    cost = ctx.cost if ctx is not None else None
    pre_bw = chip.preload_noc_bw
    tiers = chip.mem_tiers
    last = len(tiers) - 1
    chains = [0.0] * (last + 1)
    noc_chain = 0.0
    for d in plan.decisions:
        p = d.preload_plan
        if p is None or not (p.hbm_bytes or p.noc_preload_bytes):
            continue
        k = d.src_tier if 0 <= d.src_tier <= last else last
        if cost is not None:
            t_src = cost.tier_time(p.hbm_bytes, k)
        else:
            t_src = (p.hbm_bytes / tiers[k].bandwidth + tiers[k].latency) \
                if (k > 0 and tiers[k].bandwidth) else 0.0
        t_noc = p.noc_preload_bytes / pre_bw
        chains[k] += max(t_src, t_noc)
        noc_chain += t_noc
    hbm = max(max(chains), noc_chain)
    exe = sum(t.t_e_exe - t.t_s_exe for t in plan.timing)
    if plan.total_time <= 0:
        return max(hbm, exe)
    return min(max(hbm, exe), plan.total_time)


# ---------------------------------------------------------------------------
# stage-cost search state
# ---------------------------------------------------------------------------

class _StageCosts:
    """Memoized stage compiles for the cut DP.

    Stage plans are keyed by (op-signature tuple, tensor-parallel width) —
    identical layer stacks collapse every same-shape candidate range to one
    compile per width — and every compile shares one ``CompileContext``:
    curves and allocation windows are computed once for the whole search
    (sharded ops carry divided dims/bytes/flops, so their curve signatures
    differ and never collide with the unsharded ones).
    """

    def __init__(self, g: OpGraph, member: ChipConfig, design: str,
                 max_orders: int, max_exact_ops: int,
                 pod: Optional[ChipConfig] = None):
        self.g = g
        self.member = member
        self.design = design
        self.max_orders = max_orders
        self.max_exact_ops = max_exact_ops
        self.pod = pod               # pod config pricing collectives (§9)
        self.ctx = CompileContext(member)
        self.num_layers = g.num_layers
        self._sigs = [op_curve_signature(op) for op in g.ops]
        starts, first, last_end = _layer_starts(g)
        self._starts, self._first, self._last_end = starts, first, last_end
        # layer uniformity: identical per-layer signatures let deep stages
        # extrapolate from truncations (MoE stacks with dense prefixes are
        # not uniform and always schedule exactly)
        sig0 = self._layer_sig(0)
        self.uniform = all(self._layer_sig(i) == sig0
                           for i in range(1, g.num_layers))
        self._memo: dict = {}

    def _layer_sig(self, i: int) -> tuple:
        lo = self._starts[i]
        hi = self._starts[i + 1] if i + 1 < self.num_layers else self._last_end
        return tuple(self._sigs[lo:hi])

    def _compile(self, sub: OpGraph) -> ExecutionPlan:
        from repro.core.baselines import build_plan
        return build_plan(sub, self.member, self.design,
                          max_orders=self.max_orders, ctx=self.ctx)

    def stage(self, lo: int, hi: int,
              width: int = 1) -> tuple[OpGraph, ExecutionPlan, float, float]:
        """(sub-graph, plan, per-microbatch time, steady interval) for
        decoder layers [lo, hi), optionally sharded ``width`` ways (the
        returned sub-graph is then the per-chip shard)."""
        sub = stage_subgraph(self.g, lo, hi, self.num_layers)
        if width > 1:
            sub, _ = shard_graph(sub, width)
        key = (lo == 0, hi == self.num_layers, width,
               tuple(self._sigs[self._op_lo(lo):self._op_hi(hi)]))
        got = self._memo.get(key)
        if got is None:
            got = self._solve(sub, lo, hi, width)
            self._memo[key] = got
        plan, time, ival = got
        return sub, plan, time, ival

    def collective(self, lo: int, hi: int, width: int) -> tuple[float, tuple]:
        """(time, descriptors) of the per-microbatch intra-stage collectives
        of decoder layers [lo, hi) sharded ``width`` ways — arithmetic on
        the exact sub-graph (no compile), so it stays exact even when the
        stage plan extrapolates."""
        if width <= 1 or self.pod is None:
            return 0.0, ()
        key = ("coll", lo == 0, hi == self.num_layers, width,
               tuple(self._sigs[self._op_lo(lo):self._op_hi(hi)]))
        got = self._memo.get(key)
        if got is None:
            sub = stage_subgraph(self.g, lo, hi, self.num_layers)
            _, colls = shard_graph(sub, width)
            topo = self.pod.topo
            t = sum(topo.collective_time(kind, b, width)
                    for kind, b in colls)
            got = self._memo[key] = (t, colls)
        return got

    def _op_lo(self, lo: int) -> int:
        return self._starts[lo] if lo > 0 else 0

    def _op_hi(self, hi: int) -> int:
        return self._starts[hi] if hi < self.num_layers else len(self.g.ops)

    def _solve(self, sub: OpGraph, lo: int, hi: int, width: int = 1):
        k = hi - lo
        if len(sub.ops) <= self.max_exact_ops or not self.uniform or k <= 3:
            plan = self._compile(sub)
            return plan, plan.total_time, steady_interval(
                plan, self.member, self.ctx)
        # deep uniform stage: linear layer-count extrapolation from two
        # truncations of the same flavor (both land in the memo, so every
        # deep candidate range reuses them)
        k2 = min(k - 1, 8)
        k1 = max(k2 - 2, 1)
        scale = (k - k2) / (k2 - k1)

        def probe(kk: int):
            # anchor the truncation to whichever end carries this stage's
            # prefix/suffix ops, so embed and lm_head stay in both probes
            if hi == self.num_layers and lo > 0:
                s = stage_subgraph(self.g, hi - kk, hi, self.num_layers)
            else:
                s = stage_subgraph(self.g, lo, lo + kk, self.num_layers)
            if width > 1:
                s, _ = shard_graph(s, width)
            p = self._compile(s)
            return p, p.total_time, steady_interval(p, self.member, self.ctx)

        p1, t1, i1 = probe(k1)
        p2, t2, i2 = probe(k2)
        time = max(t2 + (t2 - t1) * scale, 0.0)
        ival = max(i2 + (i2 - i1) * scale, 0.0)
        plan = dataclasses.replace(p2, total_time=time,
                                   extrapolated_from_layers=k2)
        return plan, time, min(ival, time)


# ---------------------------------------------------------------------------
# cut-point DP
# ---------------------------------------------------------------------------

def _cut_dp(costs: _StageCosts, num_stages: int, send_time: float,
            slack: Optional[int]) -> list[int]:
    """Cut points minimizing ``max_s(interval_s + send_s)`` (ties broken by
    total fill).  ``slack`` bands candidate stage depths around the
    balanced ``ceil(L/S)`` to bound the number of stage compiles; the band
    widens automatically if it admits no feasible partition."""
    L, S = costs.num_layers, num_stages
    base = -(-L // S)
    if slack is None:
        slack = L if L <= 16 else max(3, base // 3)

    def run(band: int) -> Optional[list[int]]:
        lo_k = max(1, base - band)
        hi_k = min(L, base + band)

        def stage_cost(a: int, b: int) -> float:
            if not (lo_k <= b - a <= hi_k):
                return _INF
            _, _, _, ival = costs.stage(a, b)
            return ival + (send_time if b < L else 0.0)

        # f[s][l]: min bottleneck over first l layers in s stages
        f = [[_INF] * (L + 1) for _ in range(S + 1)]
        g = [[0.0] * (L + 1) for _ in range(S + 1)]    # fill tie-break
        back = [[-1] * (L + 1) for _ in range(S + 1)]
        f[0][0] = 0.0
        for s in range(1, S + 1):
            for l in range(s, L - (S - s) + 1):
                for m in range(s - 1, l):
                    if f[s - 1][m] == _INF:
                        continue
                    if not (lo_k <= l - m <= hi_k):
                        continue
                    c = stage_cost(m, l)
                    if c == _INF:
                        continue
                    v = max(f[s - 1][m], c)
                    fill = g[s - 1][m] + costs.stage(m, l)[2]
                    if v < f[s][l] - 1e-15 or (
                            abs(v - f[s][l]) <= 1e-15 and fill < g[s][l]):
                        f[s][l], g[s][l], back[s][l] = v, fill, m
        if f[S][L] == _INF:
            return None
        cuts, l = [], L
        for s in range(S, 0, -1):
            cuts.append(l)
            l = back[s][l]
        return list(reversed(cuts))        # S cut points, last == L

    band = slack
    while True:
        cuts = run(band)
        if cuts is not None:
            return cuts
        if band >= L:
            raise RuntimeError(f"no feasible {S}-stage cut of {L} layers")
        band = min(L, max(band * 2, 1))


# ---------------------------------------------------------------------------
# planner entry
# ---------------------------------------------------------------------------

_PIPE_CACHE = PlanCache(maxsize=64)


def pipeline_cache() -> PlanCache:
    return _PIPE_CACHE


def plan_pipeline(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                  seq: int, phase: Phase = "decode",
                  design: str = "ELK-Full",
                  num_stages: Optional[int] = None,
                  microbatches: Optional[int] = None,
                  max_orders: int = 4, max_exact_ops: int = 400,
                  cut_slack: Optional[int] = None,
                  cache: bool = True,
                  _costs: Optional[_StageCosts] = None) -> PipelinePlan:
    """Partition ``cfg``'s operator graph into pipeline stages across the
    chips of ``chip`` (a pod config: ``num_chips >= 1``).

    ``num_stages`` defaults to the pod's chip count; ``microbatches``
    defaults to the stage count (the minimum keeping every stage busy in
    steady state).  The per-microbatch request count is
    ``ceil(batch / microbatches)``.

    A one-stage (or one-chip) plan degenerates to today's single-chip
    compile path, bit-identical (test-pinned).
    """
    S = num_stages if num_stages is not None else max(chip.num_chips, 1)
    S = max(1, min(S, max(chip.num_chips, 1), cfg.num_layers))
    M = microbatches if microbatches is not None else S
    M = max(M, S)
    key = plan_signature(cfg, chip, batch, seq, phase, design, S, M,
                         max_orders, max_exact_ops)
    if cache:
        hit = _PIPE_CACHE.get(key)
        if hit is not None:
            return hit

    from repro.core.elk import compile_model

    if S == 1:
        plan = compile_model(cfg, chip, batch=batch, seq=seq, phase=phase,
                             design=design, max_orders=max_orders)
        g = build_graph(cfg, batch=batch, seq=seq, phase=phase)
        st = StagePlan(0, (0, cfg.num_layers), g, plan, plan.total_time,
                       plan.total_time, 0, 0.0)
        pp = PipelinePlan(cfg.name, phase, chip.name, design,
                          max(chip.num_chips, 1), batch, batch, 1, (st,),
                          plan.total_time, plan.total_time, plan.total_time,
                          plan.total_time)
        pp = _prefer_untiered(pp, cfg, chip, batch=batch, seq=seq,
                              phase=phase, design=design,
                              num_stages=num_stages,
                              microbatches=microbatches,
                              max_orders=max_orders,
                              max_exact_ops=max_exact_ops,
                              cut_slack=cut_slack, cache=cache)
        if cache:
            _PIPE_CACHE.put(key, pp)
        return pp

    b = -(-batch // M)
    view: ChipView = chip.chip_view()
    if _costs is not None:
        costs, g = _costs, _costs.g
    else:
        g = build_graph(cfg, batch=b, seq=seq, phase=phase)
        costs = _StageCosts(g, view.chip, design, max_orders, max_exact_ops)

    starts, first, last_end = _layer_starts(g)
    # activation crossing a layer boundary: the last op of the previous
    # layer's output (rows x d_model for every supported family)
    act_bytes = g.ops[(starts[1] if cfg.num_layers > 1 else last_end) - 1] \
        .out_bytes
    send_time = act_bytes / view.inter_bw + view.inter_latency

    cuts = _cut_dp(costs, S, send_time, cut_slack)
    stages = []
    lo = 0
    for i, hi in enumerate(cuts):
        sub, plan, time, ival = costs.stage(lo, hi)
        send_b = act_bytes if hi < cfg.num_layers else 0
        send_t = send_time if hi < cfg.num_layers else 0.0
        stages.append(StagePlan(i, (lo, hi), sub, plan, time, ival,
                                send_b, send_t))
        lo = hi
    interval = max(st.effective_interval for st in stages)
    fill = sum(st.time + st.collective_time + st.send_time for st in stages)
    pp = PipelinePlan(cfg.name, phase, chip.name, design,
                      max(chip.num_chips, 1), b * M, b, M, tuple(stages),
                      interval, M * interval, fill,
                      fill + (M - 1) * interval)
    pp = _prefer_untiered(pp, cfg, chip, batch=batch, seq=seq, phase=phase,
                          design=design, num_stages=num_stages,
                          microbatches=microbatches, max_orders=max_orders,
                          max_exact_ops=max_exact_ops, cut_slack=cut_slack,
                          cache=cache)
    if cache:
        _PIPE_CACHE.put(key, pp)
    return pp


def _prefer_untiered(pp: PipelinePlan, cfg: ModelConfig, chip: ChipConfig,
                     **kw) -> PipelinePlan:
    """Staging-tier plans win strictly or not at all (DESIGN.md §10).

    Candidate schedules inside a stage compile are selected on one-pass
    latency, so a staged placement can flip the winner toward a plan with
    worse steady throughput.  Planning the pod again with its middle tiers
    stripped (exactly the two-tier baseline — usually a cache hit in any
    sweep that also plans the base pod) and keeping the tiered plan only
    when strictly better makes the tiered planner never worse by
    construction."""
    if not chip.staging_tiers:
        return pp
    base = plan_pipeline(cfg, dataclasses.replace(chip, mem_tiers=()), **kw)
    return pp if pp.batch_interval < base.batch_interval else base


# ---------------------------------------------------------------------------
# hybrid (cut x width x replicas x microbatch) search — DESIGN.md §9
# ---------------------------------------------------------------------------

def _pow2_upto(n: int) -> tuple[int, ...]:
    vals = {1, n}
    p = 2
    while p < n:
        vals.add(p)
        p *= 2
    return tuple(sorted(vals))


def _hybrid_dp(costs: _StageCosts, chips: int, widths: tuple,
               replicas: tuple, send_time: float, max_slots: int,
               slack: Optional[int]) -> Optional[list]:
    """DP over (layer boundary, chips used, replica slots) assigning each
    stage a (depth, width, replicas) triple: minimize the bottleneck
    ``max_s((interval_s + collective_s)/replicas_s + send_s)`` subject to
    ``sum(width*replicas) == chips`` (leftover chips always help the
    bottleneck as replicas, so exact use is never worse) and
    ``sum(replicas) <= max_slots`` — each replica holds one in-flight
    microbatch, so the microbatch count bounds total replication.  A
    replica overlaps preload with execution only when it alternates >= 2
    distinct microbatch groups (M >= 2*replicas); otherwise its cadence is
    the full stage latency.  Returns ``[(hi, width, replicas), ...]`` or
    ``None`` when no banded partition is feasible.
    """
    L = costs.num_layers
    combos = sorted({(w, r) for w in widths for r in replicas
                     if w * r <= chips and r <= max_slots})
    if not combos:
        return None
    if slack is None:
        slack = L if L <= 16 else max(3, _ceil_div(L, chips) // 3)

    def run(band: int) -> Optional[list]:
        f = {(0, 0, 0): (0.0, 0.0)}
        back: dict = {}
        for l in range(1, L + 1):
            for c in range(1, chips + 1):
                for s in range(1, min(max_slots, c) + 1):
                    best = bptr = None
                    for w, r in combos:
                        wc = w * r
                        if wc > c or r > s:
                            continue
                        base_k = max(1, _ceil_div(L * wc, chips))
                        lo_k = max(1, base_k - band)
                        hi_k = min(L, base_k + band, l)
                        for k in range(lo_k, hi_k + 1):
                            prev = f.get((l - k, c - wc, s - r))
                            if prev is None:
                                continue
                            _, _, t, ival = costs.stage(l - k, l, w)
                            ct, _ = costs.collective(l - k, l, w)
                            # steady overlap needs >= 2 distinct groups
                            # per replica; else pay the full latency
                            pace = ival if max_slots >= 2 * r else t
                            send = send_time if l < L else 0.0
                            eff = (pace + ct) / r + send
                            v = max(prev[0], eff)
                            fill = prev[1] + t + ct + send
                            if best is None or v < best[0] - 1e-15 or (
                                    abs(v - best[0]) <= 1e-15
                                    and fill < best[1]):
                                best = (v, fill)
                                bptr = (l - k, c - wc, s - r, w, r)
                    if best is not None:
                        f[(l, c, s)] = best
                        back[(l, c, s)] = bptr
        ends = [(f[(L, chips, s)], s)
                for s in range(1, min(max_slots, chips) + 1)
                if (L, chips, s) in f]
        if not ends:
            return None
        _, s_end = min(ends, key=lambda e: e[0])
        out = []
        state = (L, chips, s_end)
        while state != (0, 0, 0):
            pl, pc, ps, w, r = back[state]
            out.append((state[0], w, r))
            state = (pl, pc, ps)
        return list(reversed(out))

    band = slack
    while True:
        got = run(band)
        if got is not None:
            return got
        if band >= L:
            return None
        band = min(L, max(band * 2, 1))


def plan_hybrid(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                seq: int, phase: Phase = "decode",
                design: str = "ELK-Full",
                widths: Optional[tuple] = None,
                replicas: Optional[tuple] = None,
                microbatches: Optional[int] = None,
                max_orders: int = 4, max_exact_ops: int = 400,
                cut_slack: Optional[int] = None,
                cache: bool = True) -> PipelinePlan:
    """Joint (cut x tensor-parallel width x data-parallel replicas x
    microbatch count) plan over the pod (DESIGN.md §9).

    ``widths``/``replicas`` default to the powers of two up to the chip
    count.  When ``microbatches`` is None the search also sweeps the
    microbatch count downward from the pipeline default — fewer, larger
    microbatches stream each stage's weights fewer times per decode round,
    which is the lever that lets wide stages beat the pure pipeline on
    HBM-bound decode.  Plans are compared on time per request per decode
    round (``batch_interval / batch``); the pure pipeline is always
    planned alongside and returned when it is at least as good, so the
    result is **never worse** than ``plan_pipeline`` and degenerates
    bit-identically when widths and replicas are pinned to 1 (or on a
    one-chip pod).
    """
    C = max(chip.num_chips, 1)
    L = cfg.num_layers
    widths = _pow2_upto(C) if widths is None else \
        tuple(sorted({int(w) for w in widths if 1 <= int(w) <= C}))
    replicas = _pow2_upto(C) if replicas is None else \
        tuple(sorted({int(r) for r in replicas if 1 <= int(r) <= C}))
    if not widths or not replicas:
        raise ValueError("widths/replicas must contain a value in "
                         f"[1, {C}]")
    key = plan_signature(cfg, chip, batch, seq, phase, design, "hybrid",
                         widths, replicas, microbatches, max_orders,
                         max_exact_ops)
    if cache:
        hit = _PIPE_CACHE.get(key)
        if hit is not None:
            return hit

    S_pipe = max(1, min(C, L))
    shared = None
    if C > 1 and L > 1:
        # one CompileContext shared between the pure-pipeline baseline and
        # the same-microbatch hybrid candidate: plan_pipeline clamps its
        # group count to >= S_pipe, so both see the same microbatch size
        M0 = max(microbatches, S_pipe) if microbatches else S_pipe
        b0 = -(-batch // M0)
        g0 = build_graph(cfg, batch=b0, seq=seq, phase=phase)
        shared = (M0, _StageCosts(g0, chip.chip_view().chip, design,
                                  max_orders, max_exact_ops, pod=chip))
    pipe = plan_pipeline(cfg, chip, batch=batch, seq=seq, phase=phase,
                         design=design, microbatches=microbatches,
                         max_orders=max_orders, max_exact_ops=max_exact_ops,
                         cut_slack=cut_slack, cache=cache,
                         _costs=shared[1] if shared else None)
    best = pipe
    if C > 1 and L > 1 and (widths != (1,) or replicas != (1,)):
        if microbatches is not None:
            m_cands = [max(microbatches, 1)]
        else:
            m_cands = sorted({S_pipe, max(S_pipe // 2, 1), 1}, reverse=True)
        for M in m_cands:
            hp = _plan_hybrid_at(cfg, chip, batch, seq, phase, design,
                                 widths, replicas, M, max_orders,
                                 max_exact_ops, cut_slack,
                                 costs=shared[1]
                                 if shared and shared[0] == M else None)
            if hp is not None and (hp.batch_interval / hp.batch
                                   < best.batch_interval / best.batch):
                best = hp
    if chip.staging_tiers:
        # same strictly-better-only rule as _prefer_untiered: a staged
        # hybrid candidate must beat the whole untiered hybrid search
        base = plan_hybrid(cfg, dataclasses.replace(chip, mem_tiers=()),
                           batch=batch, seq=seq, phase=phase, design=design,
                           widths=widths, replicas=replicas,
                           microbatches=microbatches, max_orders=max_orders,
                           max_exact_ops=max_exact_ops, cut_slack=cut_slack,
                           cache=cache)
        if not (best.batch_interval / best.batch
                < base.batch_interval / base.batch):
            best = base
    if cache:
        _PIPE_CACHE.put(key, best)
    return best


def _plan_hybrid_at(cfg: ModelConfig, chip: ChipConfig, batch: int,
                    seq: int, phase: Phase, design: str, widths: tuple,
                    replicas: tuple, M: int, max_orders: int,
                    max_exact_ops: int, cut_slack: Optional[int],
                    costs: Optional[_StageCosts] = None
                    ) -> Optional[PipelinePlan]:
    """The best hybrid partition at a fixed microbatch count (or None when
    the (widths, replicas, M) grid admits no exact-chip-count partition)."""
    C = max(chip.num_chips, 1)
    b = -(-batch // M)
    view = chip.chip_view()
    if costs is not None:
        g = costs.g
    else:
        g = build_graph(cfg, batch=b, seq=seq, phase=phase)
        costs = _StageCosts(g, view.chip, design, max_orders, max_exact_ops,
                            pod=chip)
    starts, first, last_end = _layer_starts(g)
    act_bytes = g.ops[(starts[1] if cfg.num_layers > 1 else last_end) - 1] \
        .out_bytes
    send_time = act_bytes / view.inter_bw + view.inter_latency
    assign = _hybrid_dp(costs, C, widths, replicas, send_time, M, cut_slack)
    if assign is None:
        return None
    stages = []
    lo = 0
    for i, (hi, w, r) in enumerate(assign):
        sub, plan, time, ival = costs.stage(lo, hi, w)
        ct, colls = costs.collective(lo, hi, w)
        if M < 2 * r:                  # no cross-group overlap (see DP)
            ival = time
        last = hi >= cfg.num_layers
        stages.append(StagePlan(i, (lo, hi), sub, plan, time, ival,
                                0 if last else act_bytes,
                                0.0 if last else send_time,
                                w, r, ct, colls))
        lo = hi
    interval = max(st.effective_interval for st in stages)
    fill = sum(st.time + st.collective_time + st.send_time for st in stages)
    return PipelinePlan(cfg.name, phase, chip.name, design, C, b * M, b, M,
                        tuple(stages), interval, M * interval, fill,
                        fill + (M - 1) * interval)


def replicated_plan(cfg: ModelConfig, chip: ChipConfig, *, batch: int,
                    seq: int, phase: Phase = "decode",
                    design: str = "ELK-Full",
                    max_orders: int = 4) -> ExecutionPlan:
    """Data-parallel baseline: every member chip replicates the full model
    and serves ``batch / num_chips`` requests.  Its steady-state interval
    per pod decode round is the member plan's ``total_time`` — step ``t+1``
    of the same requests cannot start before step ``t``'s sampled tokens,
    so the per-step fill/stall is paid every round."""
    from repro.core.elk import compile_model
    view = chip.chip_view()
    b = -(-batch // max(chip.num_chips, 1))
    return compile_model(cfg, view.chip, batch=b, seq=seq, phase=phase,
                         design=design, max_orders=max_orders)
