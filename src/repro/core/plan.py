"""Execution plan artifacts produced by the ELK scheduler."""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.graph import OpGraph
from repro.core.partition import ExecPlan, PreloadPlan


@dataclasses.dataclass
class OpTiming:
    t_s_pre: float = 0.0
    t_e_pre: float = 0.0
    t_s_exe: float = 0.0
    t_e_exe: float = 0.0


@dataclasses.dataclass
class OpDecision:
    op_idx: int
    preload_number: int              # residents during this op's execution
    exec_plan: ExecPlan
    preload_plan: Optional[PreloadPlan]   # this op's own preload-state plan
    stall: float = 0.0               # interconnect-contention stall charged here
    # memory tier the weight block is preloaded from (DESIGN.md §10);
    # -1 = the chip's backing tier (legacy two-level plans)
    src_tier: int = -1


@dataclasses.dataclass
class Breakdown:
    """Fig. 18(a) categories, in seconds."""
    preload_only: float = 0.0
    execute_only: float = 0.0
    overlapped: float = 0.0
    interconnect_stall: float = 0.0

    @property
    def total(self) -> float:
        return (self.preload_only + self.execute_only + self.overlapped
                + self.interconnect_stall)


@dataclasses.dataclass
class Utilization:
    hbm: float = 0.0
    interconnect: float = 0.0
    flops: float = 0.0
    achieved_tflops: float = 0.0


@dataclasses.dataclass
class ExecutionPlan:
    graph: OpGraph
    chip_name: str
    design: str                       # Basic | Static | ELK-Dyn | ELK-Full | Ideal
    decisions: list[OpDecision]
    preload_order: list[int]          # op indices in preload-issue sequence
    timing: list[OpTiming]
    total_time: float
    breakdown: Breakdown
    util: Utilization
    extrapolated_from_layers: int = 0  # 0 = exact full-model schedule
    # True when the compile-level fusion knob was on AND the fused graph
    # won the base-vs-fused selection (plan.graph then contains FusedOps).
    fusion: bool = False

    @property
    def mean_preload_number(self) -> float:
        return sum(d.preload_number for d in self.decisions) / max(
            len(self.decisions), 1)

    def edit_distance(self) -> float:
        """Mean displacement of ops between preload order and exec order
        (paper §6.2 reports an average edit distance of 2.9 steps)."""
        n = len(self.preload_order)
        if not n:
            return 0.0
        return sum(abs(pos - op) for pos, op in
                   enumerate(self.preload_order)) / n
