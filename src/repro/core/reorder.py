"""Preload order permutation (paper §4.4).

Reordering preloads (1) dodges interconnect "rush hours" and (2) shortens the
on-chip lifespan of large operators' preload footprints (Fig. 13).

Search space control, exactly as §4.4 prescribes:

* only **HBM-heavy** ops are reordered (tensor size above the layer average;
  the paper: 289 of OPT-30B's 2269 ops carry 99.8% of HBM volume);
* only **within one layer**; the same order is replayed across identical
  layers (LLMs are stacks of identical blocks);
* candidate orders are generated back-to-front as a **suffix tree** (Fig. 14):
  pick the last op to preload first; prune any branch whose co-resident set
  cannot fit on-chip (ops preloaded before a delayed op but executing after
  it must stay resident simultaneously);
* orders are additionally bounded by an **edit distance** derived from the
  free SRAM after minimal preload spaces are accounted.
"""

from __future__ import annotations

from typing import Iterator, Optional, Sequence

from repro.chip.config import ChipConfig
from repro.core.graph import OpGraph
from repro.core.pipeline import CompileContext


def heavy_ops_in_layer(graph: OpGraph) -> list[int]:
    lo, hi = graph.layer_span
    return [i for i in range(lo, hi) if graph.hbm_heavy(i)]


def _min_preload_spaces(graph: OpGraph, chip: ChipConfig,
                        idxs: Sequence[int],
                        ctx: Optional[CompileContext] = None) -> dict[int, int]:
    ctx = ctx or CompileContext(chip)
    out = {}
    for i in idxs:
        op = graph.ops[i]
        ep = ctx.curves.exec_plans(op)[-1]            # smallest exec plan
        pp = ctx.curves.preload_plans(op, ep)[-1]     # smallest preload
        out[i] = pp.space
    return out


def valid_heavy_orders(graph: OpGraph, chip: ChipConfig,
                       max_orders: int = 720,
                       max_edit_distance: int | None = None,
                       ctx: Optional[CompileContext] = None,
                       ) -> Iterator[tuple[int, ...]]:
    """Yield valid permutations of layer-0's heavy ops (execution-order
    indices), via the Fig.-14 back-to-front suffix walk with capacity
    pruning."""
    heavy = heavy_ops_in_layer(graph)
    h = len(heavy)
    if h <= 1:
        yield tuple(heavy)
        return
    spaces = _min_preload_spaces(graph, chip, heavy, ctx)
    cap = chip.usable_sram_per_core

    if max_edit_distance is None:
        # §4.4: bound edit distance by available SRAM capacity — how many
        # average heavy preloads fit simultaneously.
        avg = max(sum(spaces.values()) // h, 1)
        free = max(cap - avg, 0)
        max_edit_distance = max(1, min(h, int(free // avg) + 1))

    exec_rank = {op: r for r, op in enumerate(heavy)}

    def co_resident_fits(order: tuple[int, ...]) -> bool:
        # order[m] = op preloaded at position m. Op executed at rank r whose
        # preload position is m > r forces ops with position < m and exec
        # rank > r to co-reside.  Approximate with a prefix-window check.
        for r, op in enumerate(heavy):
            m = order.index(op)
            resident = [o for o in order[:m + 1] if exec_rank[o] >= r]
            if sum(spaces[o] for o in resident) > cap:
                return False
        return True

    count = 0
    # back-to-front generation: choose last-to-preload first (Fig. 14)
    def gen(suffix: tuple[int, ...], remaining: frozenset[int]):
        nonlocal count
        if count >= max_orders:
            return
        if not remaining:
            order = tuple(reversed(suffix))
            if co_resident_fits(order):
                count += 1
                yield order
            return
        for op in sorted(remaining):
            # edit-distance prune: op's preload position would be
            # len(remaining)-1 .. check displacement vs its exec rank
            pos = len(remaining) - 1
            if abs(pos - exec_rank[op]) > max_edit_distance:
                continue
            # capacity prune (Fig. 14): ops that execute before `op` but are
            # forced to preload before it must co-reside with it
            later = [o for o in remaining if exec_rank[o] > exec_rank[op]]
            need = spaces[op] + sum(spaces[o] for o in later)
            if need > cap:
                continue
            yield from gen(suffix + (op,), remaining - {op})

    yield from gen(tuple(), frozenset(heavy))


def apply_heavy_order(graph: OpGraph, heavy_order: Sequence[int]) -> list[int]:
    """Expand a layer-0 heavy-op permutation into a full-model preload order:
    identity everywhere, with each identical layer's heavy slots permuted the
    same way (§4.4: 'applies the same order to identical layers')."""
    lo, hi = graph.layer_span
    span = hi - lo
    heavy0 = heavy_ops_in_layer(graph)
    if list(heavy_order) == heavy0 or not heavy0:
        return list(range(len(graph.ops)))
    # π[slot] = op: heavy preload SLOTS keep their positions, the op filling
    # each slot is permuted.  slot_off[j] holds slot offsets; src_off[j] the
    # op (offset) preloaded at that slot.
    slot_off = [h - lo for h in heavy0]
    src_off = [h - lo for h in heavy_order]
    # layer signature check: apply only to layers whose op names match layer 0
    names0 = [graph.ops[lo + k].name.split(".", 1)[-1] for k in range(span)]

    order = list(range(len(graph.ops)))
    base = lo
    while base + span <= len(graph.ops):
        names = [graph.ops[base + k].name.split(".", 1)[-1]
                 for k in range(span)]
        if names != names0:
            break
        for slot, src in zip(slot_off, src_off):
            order[base + slot] = base + src
        base += span
    return order


def best_reordered_plan(scheduler, graph: OpGraph, chip: ChipConfig,
                        max_orders: int = 64, design: str = "ELK-Full",
                        parallel: Optional[int] = None):
    """Try candidate preload orders, schedule each (§4.2 pass per §4.4),
    return the best plan.

    ``parallel`` > 1 farms candidate orders out to a process pool; each
    worker owns a private ``CompileContext`` (caches do not cross process
    boundaries) and the earliest-candidate-wins tie-break of the serial
    loop is preserved, so results are identical either way.
    """
    ctx = getattr(scheduler, "ctx", None)
    orders = [apply_heavy_order(graph, horder) for horder in
              valid_heavy_orders(graph, chip, max_orders=max_orders, ctx=ctx)]
    if parallel and parallel > 1 and len(orders) > 1 \
            and _pool_safe(scheduler):
        knobs = dict(max_preload=scheduler.max_preload,
                     exec_space_cap=scheduler.exec_space_cap,
                     static_preload_frac=scheduler.static_preload_frac,
                     exec_fastest=scheduler.exec_fastest)
        best = _parallel_best(graph, chip, orders, design, parallel, knobs)
        if best is not None:
            return best
    best = None
    for pi in orders:
        plan = scheduler.schedule(pi, design=design)
        if best is None or plan.total_time < best.total_time:
            best = plan
    if best is None:
        best = scheduler.schedule(design=design)
    return best


def _pool_safe(scheduler) -> bool:
    """Workers rebuild the scheduler from its knobs; a custom cost model
    would not survive the trip, so such schedulers stay on the serial path."""
    from repro.core.cost_model import AnalyticCostModel
    return type(scheduler.cost) is AnalyticCostModel


def _eval_order_chunk(payload):
    """Worker: schedule a chunk of candidate orders with the caller's
    scheduler knobs, return the chunk's best plan and its global candidate
    index (for deterministic tie-breaks)."""
    from repro.core.scheduler import Scheduler
    graph, chip, design, knobs, chunk = payload
    sched = Scheduler(graph, chip, **knobs)
    best = None
    for idx, pi in chunk:
        plan = sched.schedule(pi, design=design)
        if best is None or plan.total_time < best[1].total_time:
            best = (idx, plan)
    return best


def _parallel_best(graph, chip, orders, design, workers, knobs):
    """Evaluate candidate orders on a spawn pool; None on pool failure (the
    caller falls back to the serial loop).  Spawn, not fork: the parent has
    usually initialized multithreaded JAX, and forking it can deadlock a
    worker.  Workers only import the (numpy-level) scheduler stack, so the
    spawn cost is import-bounded and paid once per pool."""
    import multiprocessing as mp
    from concurrent.futures import ProcessPoolExecutor

    workers = min(workers, len(orders))
    chunks = [[] for _ in range(workers)]
    for idx, pi in enumerate(orders):
        chunks[idx % workers].append((idx, pi))
    try:
        mp_ctx = mp.get_context("spawn")
    except ValueError:
        mp_ctx = None
    try:
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=mp_ctx) as pool:
            results = list(pool.map(
                _eval_order_chunk,
                [(graph, chip, design, knobs, ch) for ch in chunks if ch]))
    except Exception:  # noqa: BLE001 — optional acceleration only: spawn
        # can fail in exotic parents (no importable __main__, exhausted
        # fds, BrokenProcessPool); the serial loop is always correct
        return None
    results = [r for r in results if r is not None]
    if not results:
        return None
    # serial loop keeps the earliest candidate on ties
    _, plan = min(results, key=lambda r: (r[1].total_time, r[0]))
    return plan
