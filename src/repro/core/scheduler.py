"""Two-level inductive operator scheduling (paper §4.2) + plan finalization.

Search (backward induction, Lemma 4.1 / Theorem 4.2)
----------------------------------------------------
Operators execute in graph order; preloads are issued sequentially in a
(possibly reordered, §4.4) preload order ``pi``.  The decision variable per
operator ``i`` is the *cumulative issue count* ``c_i`` — how many preloads
(positions in ``pi``) have been issued before ``exec(i)`` starts.  The
paper's "preload number" is ``p_i = c_i - (i+1)`` = operators resident
on-chip in preload state while ``i`` executes.

Walking backward from the last operator, each step enumerates feasible
``c_i`` (memory-checked by the §4.3 allocator) and keeps the one minimizing
the *current-to-end* time — exactly Fig. 10.  Hardware rules (§4.5) are
honored: preloads are sequential; preload position ``m >= c_i`` cannot start
until ``exec(i)`` finishes; an operator must be preloaded before executing;
MoE expert preloads cannot be issued before their router executes (§7).

Finalization (forward)
----------------------
The backward pass may re-decide a resident op's preload plan in several
windows (the paper leaves this implicit).  A forward re-allocation pass walks
windows in execution order, *fixing* each op's preload plan in the window
where its preload is issued, and recomputes exact start/end times, the
Fig.-18 breakdown, and utilizations.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.chip.config import ChipConfig
from repro.core.allocator import (IncrementalWindow, WindowItem,
                                  _window_cost, core_to_allocation,
                                  place_tiers)
from repro.core.cost_model import AnalyticCostModel
from repro.core.fusion import graph_fusion_signature
from repro.core.graph import OpGraph
from repro.core.partition import ExecPlan, PreloadPlan
from repro.core.pipeline import CompileContext
from repro.core.plan import (Breakdown, ExecutionPlan, OpDecision, OpTiming,
                             Utilization)

_NEG_INF = -math.inf


class Scheduler:
    """§4.2 scheduler for one operator graph on one chip.

    All Pareto curves come from the ``CompileContext``'s ``PlanCurveCache``
    and every allocation window goes through its ``WindowCache`` — pass one
    shared ``ctx`` to amortize curve enumeration and window solves across
    Scheduler instances, candidate preload orders, and §6.1 designs.  With
    ``ctx=None`` the scheduler builds a private context (a cold compile).
    """

    def __init__(self, graph: OpGraph, chip: ChipConfig,
                 cost: Optional[AnalyticCostModel] = None,
                 max_preload: int = 64,
                 exec_space_cap: Optional[int] = None,
                 static_preload_frac: Optional[float] = None,
                 exec_fastest: bool = False,
                 ctx: Optional[CompileContext] = None):
        self.graph = graph
        self.chip = chip
        if ctx is not None:
            assert ctx.chip == chip, "CompileContext bound to a different chip"
            if cost is not None and cost is not ctx.cost:
                # curves come from ctx.cost; a different local cost model
                # would silently produce an inconsistent schedule
                raise ValueError("pass cost through the CompileContext, "
                                 "not alongside it")
        self.ctx = ctx or CompileContext(chip, cost)
        self.cost = self.ctx.cost
        self.max_preload = max_preload
        # Baseline knobs (§6.1): a fixed execution-space budget (Static), a
        # fixed preload-plan policy, and Basic's "maximize execution space"
        # rule; all None/False = full ELK behaviour.
        self.exec_space_cap = exec_space_cap
        self.static_preload_frac = static_preload_frac
        self.exec_fastest = exec_fastest
        # invariant per chip/graph; cached off the property hot paths
        self._topo_sig = chip.topo_signature
        self._fusion_sig = graph_fusion_signature(graph)
        self._mem_sig = chip.mem_signature
        self._preload_bw = chip.preload_noc_bw
        self.curves = [self._curves(op) for op in graph.ops]
        # which memory tier each op's weight block is sourced from
        # (DESIGN.md §10); all-backing for the default two-tier chips.
        # The fastest-exec chain is the steady-interval floor: staging
        # below it costs latency without buying throughput.
        exe_floor = sum(min(p.time for p in curve)
                        for curve in self.curves if curve)
        self._tier_of = place_tiers(chip, graph.ops, self.cost,
                                    floor=exe_floor).tier_of
        self._pre_memo: dict = {}

    # -- plan curves ---------------------------------------------------------
    def _curves(self, op) -> list[ExecPlan]:
        if self.exec_space_cap is not None:
            return self.ctx.curves.exec_plans_capped(op, self.exec_space_cap)
        return self.ctx.curves.exec_plans(op)

    def _exec_curve(self, i: int) -> list[ExecPlan]:
        return self.curves[i]

    def _pre_curve(self, i: int, exec_idx: int) -> list[PreloadPlan]:
        key = (i, exec_idx)
        got = self._pre_memo.get(key)
        if got is None:
            op = self.graph.ops[i]
            ep = self.curves[i][exec_idx]
            if self.static_preload_frac is not None:
                # Static baseline: largest- or smallest-footprint plan only
                got = self.ctx.curves.preload_plans_static(
                    op, ep, self.static_preload_frac >= 0.5)
            else:
                got = self.ctx.curves.preload_plans(op, ep)
            self._pre_memo[key] = got
        return got

    # -- window cache helpers -------------------------------------------------
    def _window_key(self, items, cap: int):
        uid_of = self.ctx.curves.uid_of
        parts = []
        for it in items:
            uid = uid_of(it.plans)
            if uid is None:
                return None
            parts.append((uid, it.fixed, it.fixed_choice, it.tier))
        # topology signature: window costs fold in topology hop weights, so
        # a topology change must miss (contexts are per-chip, but be
        # explicit).  The fusion signature plays the same role for the §8
        # pass: fused and unfused schedules share a context but must never
        # share a window solve, and the memory signature for §10: per-tier
        # capacities change which greedy trace a window solves against.
        return (cap, self._topo_sig, self._fusion_sig, self._mem_sig,
                tuple(parts))

    # -- main entry -----------------------------------------------------------
    def schedule(self, preload_order: Optional[Sequence[int]] = None,
                 design: str = "ELK-Dyn") -> ExecutionPlan:
        graph, chip = self.graph, self.chip
        n = len(graph.ops)
        pi = list(preload_order) if preload_order is not None else list(range(n))
        assert sorted(pi) == list(range(n)), "preload order must be a permutation"
        pos = [0] * n
        for m, j in enumerate(pi):
            pos[j] = m

        # MoE/etc: cap on c_i from preload deps (op j preloadable only after
        # its dep executed): while i <= dep(j), c_i <= pos[j].
        dep_cap = [n] * (n + 1)
        for j, op in enumerate(graph.ops):
            if op.preload_dep >= 0:
                for i in range(0, min(op.preload_dep + 1, n)):
                    dep_cap[i] = min(dep_cap[i], pos[j])

        # c_min(i): every op executed by step i must have been preloaded.
        c_min = [0] * n
        run = 0
        for i in range(n):
            run = max(run, pos[i] + 1)
            c_min[i] = run

        # ---- backward induction -------------------------------------------
        exec_choice = [0] * n              # index into exec curve
        c_seq = [0] * (n + 1)
        c_seq[n] = n
        tau_s_exe = [0.0] * (n + 1)        # time-before-end of exec start
        tau_s_pre = [_NEG_INF] * (n + 1)   # per preload position
        l_exe = [0.0] * n

        cap = self.chip.usable_sram_per_core
        windows = self.ctx.windows
        for i in range(n - 1, -1, -1):
            c_next = c_seq[i + 1]
            best = None
            lo = c_min[i]
            hi = min(c_next, i + 1 + self.max_preload, dep_cap[i])
            hi = max(hi, lo)
            # Window family for exec(i): the resident set — ops issued (< c)
            # and not yet executed (> i), the paper's Fig.-4 capacity
            # tradeoff — grows by one preload per step of c, so the greedy
            # descent warm-starts incrementally instead of re-running cold.
            if self.exec_fastest:
                # Basic (§6.1): execution space maximized, preloads squeeze
                # into the remainder.
                exec_item = WindowItem(i, "exec", self._exec_curve(i),
                                       fixed=True, fixed_choice=0)
            else:
                exec_item = WindowItem(i, "exec", self._exec_curve(i))
            fam = IncrementalWindow(self.chip, cap)
            fam.add_item(exec_item)
            added = 0
            lo_alloc = None
            lo_n_items = 1
            for c in range(lo, hi + 1):
                while added < c:
                    j = pi[added]
                    if j > i:
                        fam.add_item(WindowItem(
                            j, "preload",
                            self._pre_curve(j, exec_choice[j])))
                    added += 1
                # preloads *issued during* this window ([c, c_next)) put
                # their HBM-controller->core delivery bytes on the
                # interconnect here; residents' delivery was charged to
                # their issuing window.
                extra_noc = sum(self._preload_noc_estimate(pi[m], exec_choice)
                                for m in range(c, c_next))
                core = None
                key = self._window_key(fam.items, cap)
                if key is not None:
                    core = windows.get(key)
                if core is None:
                    core = fam.solve_core()
                    if key is not None:
                        windows.put(key, core)
                alloc = core_to_allocation(self.chip, fam.items, core,
                                           extra_noc)
                if c == lo:
                    lo_alloc = alloc
                    lo_n_items = len(fam.items)
                if not alloc.feasible:
                    # residents grow with c => larger c stays infeasible
                    if c > lo:
                        break
                    continue
                lexe = alloc.exec_time + max(
                    0.0, alloc.noc_time - alloc.exec_time)
                # schedule new preload positions [c, c_next) latest-first
                tau_pre_local = {}
                nxt = tau_s_pre[c_next] if c_next < n else _NEG_INF
                for m in range(c_next - 1, c - 1, -1):
                    j = pi[m]
                    t_end = max(tau_s_exe_at(tau_s_exe, j, n), nxt)
                    lpre = self._preload_time(j, exec_choice)
                    tau_pre_local[m] = t_end + lpre
                    nxt = tau_pre_local[m]
                blocker = tau_pre_local.get(c, tau_s_pre[c] if c < n else _NEG_INF)
                tau_e = max(tau_s_exe[i + 1], blocker, 0.0)
                tau_s = tau_e + lexe
                if best is None or tau_s < best[0] - 1e-15:
                    best = (tau_s, c, alloc, tau_pre_local, lexe)
            if best is None:
                # cannot fit even c = c_min: fall back to minimal window with
                # smallest plans (degenerate but schedulable)
                c = lo
                items = fam.items[:lo_n_items]
                choice = {it.op_idx: len(it.plans) - 1 for it in items}
                extra_noc = sum(self._preload_noc_estimate(pi[m], exec_choice)
                                for m in range(c, c_next))
                cost, e, d, nt = _window_cost(self.chip, items, choice,
                                              extra_noc)
                alloc = dataclasses.replace(
                    lo_alloc, feasible=True, choices=choice, exec_time=e,
                    dist_time=d, noc_time=nt, cost=cost)
                lexe = alloc.exec_time
                best = (tau_s_exe[i + 1] + lexe, c, alloc, {}, lexe)
            tau_s, c, alloc, tau_pre_local, lexe = best
            c_seq[i] = c
            tau_s_exe[i] = tau_s
            l_exe[i] = lexe
            for m, v in tau_pre_local.items():
                tau_s_pre[m] = v
            exec_choice[i] = alloc.choices[i]

        # ---- forward finalization ------------------------------------------
        return self._finalize(pi, pos, c_seq, exec_choice, design)

    def _preload_noc_estimate(self, j: int, exec_choice: list[int]) -> float:
        """Delivery bytes of op j's preload (min-space plan estimate; the
        forward finalization recomputes with the bound plan)."""
        return self._pre_curve(j, exec_choice[j])[-1].noc_preload_bytes

    def _preload_time(self, j: int, exec_choice: list[int]) -> float:
        """Paper §4.2: max(source-tier roofline time, interconnect transfer
        time).  The backward pass prices every preload at the *backing*
        tier, whatever its placement: issue decisions stay identical to the
        untiered schedule, so staging can only shorten the per-tier queue
        chains the forward finalization computes — never perturb the
        window structure into a worse plan."""
        op = self.graph.ops[j]
        pre = self._pre_curve(j, exec_choice[j])
        plan = pre[-1]  # minimum-space estimate; finalization refines
        t_src = self.cost.tier_time(plan.hbm_bytes, self.chip.backing_tier)
        t_noc = plan.noc_preload_bytes / self._preload_bw
        return max(t_src, t_noc)

    # -- finalization ----------------------------------------------------------
    def _finalize(self, pi, pos, c_seq, exec_choice, design) -> ExecutionPlan:
        graph, chip, n = self.graph, self.chip, len(self.graph.ops)
        # Two-phase binding.  Phase 1: allocate every window independently
        # and record each resident op's chosen preload plan per window.
        # Phase 2: bind each op to its *min-space* choice across all windows
        # it is resident in — the loaded plan must fit the tightest window
        # it lives through, and binding at issue time (where space is
        # plentiful) was measured to starve later windows so badly that
        # ELK-Dyn fell behind Basic on KV-heavy shapes.
        seen_choice: dict[int, int] = {}
        for i in range(n):
            residents = [j for j in pi[:c_seq[i]] if j > i]
            items = [WindowItem(i, "exec", self._exec_curve(i),
                                fixed=True, fixed_choice=exec_choice[i])]
            for j in residents:
                items.append(WindowItem(
                    j, "preload", self._pre_curve(j, exec_choice[j])))
            alloc, _ = self._allocate_window_items(items, 0.0)
            for j in residents:
                seen_choice[j] = max(seen_choice.get(j, 0),
                                     alloc.choices[j])

        bound_pre: dict[int, PreloadPlan] = {}
        bound_pre_idx: dict[int, int] = {}
        for j, idx in seen_choice.items():
            curve = self._pre_curve(j, exec_choice[j])
            bound_pre_idx[j] = idx
            bound_pre[j] = curve[idx]

        stall = [0.0] * n
        lexe = [0.0] * n
        dist = [0.0] * n
        for i in range(n):
            residents = [j for j in pi[:c_seq[i]] if j > i]
            items = [WindowItem(i, "exec", self._exec_curve(i),
                                fixed=True, fixed_choice=exec_choice[i])]
            for j in residents:
                curve = self._pre_curve(j, exec_choice[j])
                items.append(WindowItem(j, "preload", curve, fixed=True,
                                        fixed_choice=bound_pre_idx[j]))
            extra_noc = 0.0
            for m in range(c_seq[i], c_seq[i + 1]):
                j = pi[m]
                if j in bound_pre:
                    extra_noc += bound_pre[j].noc_preload_bytes
                else:
                    extra_noc += self._preload_noc_estimate(j, exec_choice)
            alloc, _ = self._allocate_window_items(items, extra_noc)
            lexe[i] = alloc.exec_time
            stall[i] = max(0.0, alloc.noc_time - alloc.exec_time)
        # ops never resident anywhere (executed immediately after preload /
        # c window boundaries): bind min-space plan
        for j in range(n):
            if j not in bound_pre:
                curve = self._pre_curve(j, exec_choice[j])
                bound_pre_idx[j] = len(curve) - 1
                bound_pre[j] = curve[-1]
            dist[j] = bound_pre[j].dist_time

        # exact forward timing
        timing = [OpTiming() for _ in range(n)]
        # c_seq is nondecreasing in i; position m is blocked by every i with
        # c_i <= m; the binding (latest-exec) one is max{i : c_i <= m}.
        blocker_of = [-1] * n
        b, idx = -1, 0
        for m in range(n):
            while idx < n and c_seq[idx] <= m:
                b = idx
                idx += 1
            blocker_of[m] = b

        # each source tier serves its preloads sequentially (§4.5, per
        # controller group) — one free-at time per tier; a single-tier chip
        # reduces to the original global chain bit-for-bit
        pre_bw = self._preload_bw
        tier_free: dict[int, float] = {}
        for m in range(n):
            j = pi[m]
            t_blocked = (timing[blocker_of[m]].t_e_exe
                         if blocker_of[m] >= 0 else 0.0)
            dep = graph.ops[j].preload_dep
            t_dep = timing[dep].t_e_exe if dep >= 0 else 0.0
            tk = self._tier_of[j]
            t_start = max(tier_free.get(tk, 0.0), t_blocked, t_dep)
            plan = bound_pre[j]
            lpre = max(self.cost.tier_time(plan.hbm_bytes, tk),
                       plan.noc_preload_bytes / pre_bw)
            timing[j].t_s_pre = t_start
            timing[j].t_e_pre = t_start + lpre
            tier_free[tk] = timing[j].t_e_pre
            # exec timing interleaves: fill exec times for ops whose preload
            # completed — handled in second sweep below.

        # exec sweep (depends on preload completion; preload blocked-by-exec
        # constraint resolved by iterating to fixpoint, 2 passes suffice
        # because blocking only delays preloads of *later* windows)
        for _ in range(3):
            t_prev = 0.0
            for i in range(n):
                t_s = max(t_prev, timing[i].t_e_pre)
                timing[i].t_s_exe = t_s
                timing[i].t_e_exe = t_s + dist[i] + lexe[i] + stall[i]
                t_prev = timing[i].t_e_exe
            tier_free = {}
            for m in range(n):
                j = pi[m]
                t_blocked = (timing[blocker_of[m]].t_e_exe
                             if blocker_of[m] >= 0 else 0.0)
                dep = graph.ops[j].preload_dep
                t_dep = timing[dep].t_e_exe if dep >= 0 else 0.0
                tk = self._tier_of[j]
                t_start = max(tier_free.get(tk, 0.0), t_blocked, t_dep)
                plan = bound_pre[j]
                lpre = max(self.cost.tier_time(plan.hbm_bytes, tk),
                           plan.noc_preload_bytes / pre_bw)
                timing[j].t_s_pre = t_start
                timing[j].t_e_pre = t_start + lpre
                tier_free[tk] = timing[j].t_e_pre

        total = timing[n - 1].t_e_exe if n else 0.0
        decisions = [OpDecision(i, c_seq[i] - (i + 1),
                                self._exec_curve(i)[exec_choice[i]],
                                bound_pre.get(i), stall[i],
                                src_tier=self._tier_of[i])
                     for i in range(n)]
        breakdown = _breakdown(timing, stall, total)
        util = _utilization(self, bound_pre, decisions, total)
        return ExecutionPlan(graph, chip.name, design, decisions, pi, timing,
                             total, breakdown, util)

    def _allocate_window_items(self, items, extra_noc: float = 0.0):
        cap = self.chip.usable_sram_per_core
        key = self._window_key(items, cap)
        core = self.ctx.windows.get(key) if key is not None else None
        if core is None:
            win = IncrementalWindow(self.chip, cap)
            for it in items:
                win.add_item(it)
            core = win.solve_core()
            if key is not None:
                self.ctx.windows.put(key, core)
        alloc = core_to_allocation(self.chip, items, core, extra_noc)
        if not alloc.feasible:
            choice = {it.op_idx: (it.fixed_choice if it.fixed
                                  else len(it.plans) - 1) for it in items}
            cost, e, d, nt = _window_cost(self.chip, items, choice, extra_noc)
            alloc = dataclasses.replace(alloc, feasible=True, choices=choice,
                                        exec_time=e, dist_time=d, noc_time=nt,
                                        cost=cost)
        return alloc, items


def tau_s_exe_at(tau_s_exe: list[float], j: int, n: int) -> float:
    return tau_s_exe[j] if j <= n else 0.0


def _breakdown(timing: list[OpTiming], stall: list[float],
               total: float) -> Breakdown:
    """Interval arithmetic over preload vs exec busy spans (Fig. 18a)."""
    events = []
    for t in timing:
        if t.t_e_pre > t.t_s_pre:
            events.append((t.t_s_pre, t.t_e_pre, "p"))
        if t.t_e_exe > t.t_s_exe:
            events.append((t.t_s_exe, t.t_e_exe, "e"))
    pts = sorted({0.0, total} | {x for s, e, _ in events for x in (s, e)})
    b = Breakdown(interconnect_stall=sum(stall))
    for a, z in zip(pts, pts[1:]):
        mid = (a + z) / 2
        has_p = any(s <= mid < e for s, e, k in events if k == "p")
        has_e = any(s <= mid < e for s, e, k in events if k == "e")
        span = z - a
        if has_p and has_e:
            b.overlapped += span
        elif has_p:
            b.preload_only += span
        elif has_e:
            b.execute_only += span
    # stall time was folded inside exec spans; remove it from execute/overlap
    b.execute_only = max(0.0, b.execute_only - sum(stall))
    return b


def _utilization(sched: "Scheduler", bound_pre, decisions, total
                 ) -> Utilization:
    chip = sched.chip
    if total <= 0:
        return Utilization()
    hbm_bytes = sum(p.hbm_bytes for p in bound_pre.values())
    noc_occ = sum(chip.noc_occupancy(0.0, p.noc_preload_bytes,
                                     p.noc_dist_bytes)
                  for p in bound_pre.values())
    noc_occ += chip.noc_occupancy(
        sum(d.exec_plan.noc_exec_bytes for d in decisions), 0.0)
    flops = sum(op.flops for op in sched.graph.ops)
    hbm = (hbm_bytes / (chip.hbm_bw * total)) if chip.hbm_bw else 0.0
    return Utilization(
        hbm=min(hbm, 1.0),
        interconnect=min(noc_occ / total, 1.0),
        flops=min(flops / (chip.total_flops * total), 1.0),
        achieved_tflops=flops / total / 1e12,
    )
