"""Deterministic synthetic token pipeline (+ optional memmap file loader).

The training substrate needs a data source that is (a) deterministic under
restart — step ``k`` always yields the same batch, so checkpoint/resume is
bitwise reproducible, (b) cheap on CPU, (c) shaped exactly like the real
thing.  Synthetic batches are seeded by ``(seed, step)`` alone; a restored
trainer re-derives the stream from its step counter with no iterator state
to checkpoint.

``MemmapDataset`` reads a flat uint16/uint32 token file (the standard
"packed tokens" format) for running the examples against real data when a
corpus file is available.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.frontends import frontend_embeddings


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    seed: int = 0
    vocab_size: int = 32000


class SyntheticLM:
    """Markov-ish synthetic LM stream: next token depends on the previous
    one (so a trained model's loss actually falls — used by train_e2e)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (c.batch, c.seq + 1), 0, c.vocab_size)
        # structure: token_{t+1} = (token_t * 7 + 3) % V with prob .5
        flip = jax.random.bernoulli(k2, 0.5, (c.batch, c.seq + 1))
        seq = [base[:, 0]]
        for t in range(1, c.seq + 1):
            pred = (seq[-1] * 7 + 3) % c.vocab_size
            seq.append(jnp.where(flip[:, t], pred, base[:, t]))
        toks = jnp.stack(seq, axis=1)
        out = {"tokens": toks[:, :-1].astype(jnp.int32),
               "labels": toks[:, 1:].astype(jnp.int32)}
        if self.model_cfg is not None:
            out.update(frontend_embeddings(self.model_cfg, c.batch,
                                           seed=c.seed + step))
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapDataset:
    """Flat token file -> deterministic (tokens, labels) batches."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(c.seed + step)
        idx = rng.integers(0, self.n_windows, size=c.batch)
        toks = np.stack([self.tokens[i * c.seq:(i + 1) * c.seq + 1]
                         for i in idx]).astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


def make_dataset(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None,
                 path: Optional[str] = None):
    if path and os.path.exists(path):
        return MemmapDataset(path, cfg)
    return SyntheticLM(cfg, model_cfg)
