"""Gradient compression for the cross-pod (DCN-class) boundary.

At 1000+ nodes the pod-to-pod all-reduce is the scarcest bandwidth in the
system.  Two standard compressors, both pure-JAX and usable inside jit:

* ``bf16`` — cast-to-bf16 reduce (2x): lossless enough for gradients that
  are consumed by Adam's normalizing update.
* ``int8`` — per-tensor-scale int8 with **error feedback**: the
  quantization residual is carried to the next step so the compression
  bias telescopes away (Seide et al.; 4x over fp32, 2x over bf16).

The compressor wraps the gradient tree *before* the pod-axis psum; inside
a jit boundary XLA reduces the quantized payload, so the wire format is
what actually crosses the DCN.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _q_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dq_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: PyTree, error: Optional[PyTree],
                   method: str = "none"
                   ) -> tuple[PyTree, Optional[PyTree]]:
    """Returns (wire_grads, new_error_feedback).

    wire_grads carries the (de)quantized values — numerically what the
    receiving side sees; new_error is the residual to add next step."""
    if method == "none":
        return grads, error
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), error
    if method == "int8":
        if error is None:
            error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads)

        def one(g, e):
            target = g.astype(jnp.float32) + e
            q, s = _q_int8(target)
            deq = _dq_int8(q, s)
            return deq.astype(g.dtype), target - deq

        pairs = jax.tree.map(one, grads, error)
        wire = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return wire, new_err
    raise KeyError(method)


def init_error_feedback(grads: PyTree, method: str) -> Optional[PyTree]:
    if method != "int8":
        return None
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)
