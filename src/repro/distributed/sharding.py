"""Sharding rules for the (pod, data, model) production mesh.

Axis roles
----------
* ``pod``   — pure data parallelism across pods (gradients cross the pod
  boundary once per step, optionally compressed: ``distributed/compression``).
* ``data``  — within-pod data parallelism; also the ZeRO axis: optimizer
  state (and, in FSDP mode, parameters) shard over it.
* ``model`` — tensor parallelism: attention/MLP/vocab dims; also the
  expert-parallel axis for MoE and the sequence/KV axis for long-context
  serving (SP) when head counts don't divide.

Rules are path-pattern based over the parameter pytree produced by
``models.transformer.init_params``.  Every rule falls back to replication
when a dimension is not divisible by the axis size — XLA would otherwise
pad-and-reshard behind our back; an explicit fallback keeps the collective
schedule visible to the roofline analysis.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# preferred (regex over '/'-joined path) -> spec builder.  ``d`` below means
# "shard this dim over the model axis".  Dims count from the *end* so the
# stacked-blocks leading dim never shifts patterns.
_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    # name pattern                      spec for the trailing dims
    (r"embed$",                         ("model", None)),     # (V, d)
    (r"lm_head$",                       (None, "model")),     # (d, V)
    (r"(dec|enc)_pos$",                 (None, None)),
    (r"attn/w_[qkv]$",                  (None, "model")),
    (r"attn/w_o$",                      ("model", None)),
    (r"attn/b_[qkv]$",                  ("model",)),
    (r"xattn/w_[qkv]$",                 (None, "model")),
    (r"xattn/w_o$",                     ("model", None)),
    (r"(mlp|shared_mlp)/w_(gate|up)$",  (None, "model")),
    (r"(mlp|shared_mlp)/w_down$",       ("model", None)),
    (r"(mlp|shared_mlp)/b_up$",         ("model",)),
    (r"(mlp|shared_mlp)/b_down$",       (None,)),
    (r"moe/router$",                    (None, None)),
    (r"moe/w_(gate|up)$",               ("model", None, None)),  # (E,d,ff): EP
    (r"moe/w_down$",                    ("model", None, None)),
    # rwkv time-mix / channel-mix
    (r"w_[rkvg]$",                      (None, "model")),
    (r"w_decay$",                       (None, "model")),
    (r"w_o$",                           ("model", None)),
    (r"w_ck$",                          (None, "model")),
    (r"w_cv$",                          ("model", None)),
    # hymba ssm branch
    (r"ssm/w_in$",                      (None, "model")),
    (r"ssm/w_out$",                     ("model", None)),
    (r"ssm/w_dt$",                      (None, "model")),
    (r"ssm/w_bc$",                      (None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def param_pspec(path: str, shape: tuple[int, ...], mesh: Mesh,
                fsdp: bool = False, layout: str = "tp") -> P:
    """Spec for one parameter.  Leading stacked-block dims are unsharded
    (or data-sharded in FSDP mode, realizing the ELK preload state).

    ``layout='fsdp2d'``: block weights shard their largest dim over the
    *joint* (data, model) axes — no tensor-parallel activation traffic at
    all (the measured TP-16 activation gathers cost ~30x the compute bound
    for dense <=30B training; EXPERIMENTS.md §Perf iteration 2).  The
    vocab head/embedding keep their model-axis sharding (vocab-parallel
    logits are what bound the loss memory)."""
    if layout == "fsdp2d" and path.split("/")[0] in ("blocks", "prefix") \
            and len(shape) >= 2:
        dims = list(shape)
        lead = 1 if path.split("/")[0] == "blocks" else 0
        body = dims[lead:]
        order = sorted(range(len(body)), key=lambda i: -body[i])
        d_sz = _axis_size(mesh, "data")
        m_sz = _axis_size(mesh, "model")
        spec: list = [None] * len(body)
        best = order[0]
        if body[best] % (d_sz * m_sz) == 0:
            spec[best] = ("data", "model")
        else:
            placed = False
            for i in order:
                if body[i] % d_sz == 0:
                    spec[i] = "data"
                    placed = True
                    break
            for i in order:
                if spec[i] is None and body[i] % m_sz == 0:
                    spec[i] = "model"
                    break
        return P(*([None] * lead), *spec)

    trailing: tuple[Optional[str], ...] = ()
    for pat, spec in _RULES:
        if re.search(pat, path):
            trailing = spec
            break
    # validate divisibility; drop the axis if it doesn't divide
    trailing = tuple(
        (ax if ax and shape[len(shape) - len(trailing) + i]
         % _axis_size(mesh, ax) == 0 else None)
        for i, ax in enumerate(trailing))
    lead_n = len(shape) - len(trailing)
    lead: list[Optional[str]] = [None] * lead_n
    if fsdp and lead_n >= 1 and path.split("/")[0] == "blocks":
        # FSDP/ELK-preload-state: shard the stacked-blocks dim's *largest
        # unsharded trailing dim* over data.  Gathers happen layer-by-layer
        # in the streaming scan (serve/stream.py) or via XLA (train).
        cand = [i for i, ax in enumerate(trailing) if ax is None]
        sizes = shape[lead_n:]
        cand = [i for i in cand
                if sizes[i] % _axis_size(mesh, "data") == 0 and sizes[i] > 1]
        if cand:
            best = max(cand, key=lambda i: sizes[i])
            trailing = tuple("data" if i == best else ax
                             for i, ax in enumerate(trailing))
    return P(*lead, *trailing)


def param_shardings(params: PyTree, mesh: Mesh, fsdp: bool = False,
                    layout: str = "tp") -> PyTree:
    def one(path, leaf):
        spec = param_pspec(_path_str(path), np.shape(leaf), mesh, fsdp,
                           layout)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def param_specs(params: PyTree, mesh: Mesh, fsdp: bool = False,
                layout: str = "tp") -> PyTree:
    def one(path, leaf):
        return param_pspec(_path_str(path), np.shape(leaf), mesh, fsdp,
                           layout)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that jointly shard the global batch."""
    return tuple(ax for ax in ("pod", "data") if ax in mesh.shape)


def batch_pspec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def batch_shardings(batch: PyTree, mesh: Mesh) -> PyTree:
    """Batch dict sharding: dim 0 = global batch on (pod, data)."""
    bp = batch_axes(mesh)

    def one(leaf):
        nd = np.ndim(leaf)
        return NamedSharding(mesh, P(bp, *([None] * (nd - 1))))
    return jax.tree_util.tree_map(one, batch)


def cache_pspec(key: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Serving cache sharding.  KV tensors (L, B, Hkv, C, hd): batch over
    (pod, data); heads over model when divisible, otherwise the cache
    length C shards over model (sequence parallelism — the GQA small-kv
    fallback)."""
    bp = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in bp])) if bp else 1
    m = mesh.shape.get("model", 1)

    def b_ax(B):
        return bp if B % max(dp, 1) == 0 else None

    if key in ("k", "v", "k_scale", "v_scale", "cross_k", "cross_v"):
        L, B, H, C, D = shape
        if H % m == 0:
            return P(None, b_ax(B), "model", None, None)
        if C % m == 0:
            return P(None, b_ax(B), None, "model", None)
        return P(None, b_ax(B), None, None, None)
    if key == "rwkv_state":        # (L, B, H, D, D)
        L, B, H, *_ = shape
        h_ax = "model" if H % m == 0 else None
        return P(None, b_ax(B), h_ax, None, None)
    if key == "ssm_state":         # (L, B, d, N)
        L, B, d, _ = shape
        d_ax = "model" if d % m == 0 else None
        return P(None, b_ax(B), d_ax, None)
    if key == "slot_pos":
        return P(None)
    return P()                     # pos scalar etc.


def cache_shardings(cache: PyTree, mesh: Mesh) -> PyTree:
    def one(path, leaf):
        key = _path_str(path).split("/")[-1]
        # scales tuple nests one level deeper; normalize
        if key in ("0", "1"):
            key = _path_str(path).split("/")[-2]
        return NamedSharding(mesh, cache_pspec(key, np.shape(leaf), mesh))
    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain_batch(x: jax.Array, mesh: Mesh) -> jax.Array:
    """Constrain a (B, ...) activation's batch dim onto (pod, data)."""
    nd = x.ndim
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(batch_axes(mesh), *([None] * (nd - 1)))))
