"""Single-token decode attention Pallas TPU kernel.

The serving hot spot: one new query per request against a long KV cache
(the ``decode_32k`` / ``long_500k`` dry-run cells).  Decode attention is
purely memory-bound — arithmetic intensity ~= 1 FLOP/byte — so the kernel
is organized around *streaming* the KV cache HBM->VMEM once at full
bandwidth, the exact regime the ELK paper's preload engine targets (the KV
cache is the paper's canonical memory-intensive operator, §3.2).

Grid: (B, Hkv, C/bk), KV innermost with the online-softmax (max, sum, acc)
carry in VMEM scratch.  The GQA q-group (G = Hq/Hkv queries sharing one KV
head) rides along the row axis of the score tile, so the MXU sees a
(G, bk) matmul per step instead of G vector products.

Ring-buffer masking: ``slot_pos`` (absolute position per cache slot,
sentinel for unwritten slots) streams with the same block index; causal +
window predicates are evaluated against the scalar-prefetched query
position.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the Mosaic params class TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEG_INF = -1e30


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bk: int, window: int,
                   scale: float, kv_steps: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
    spos = pos_ref[...]                            # (1, bk) int32
    q_pos = qpos_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G,bk)
    ok = spos <= q_pos
    if window:
        ok &= spos > q_pos - window
    s = jnp.where(ok, s, _NEG_INF)                 # (1,bk) broadcasts over G

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, q_pos: jax.Array, *,
                     window: int = 0, bk: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); caches: (B, Hkv, C, D); slot_pos: (C,) int32;
    q_pos: scalar int32.  Returns (B, Hq, D)."""
    b, hq, d = q.shape
    hkv, c = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    bk = min(bk, c)
    assert c % bk == 0, (c, bk)
    kv_steps = c // bk
    scale = d ** -0.5

    qg = q.reshape(b, hkv, g, d)
    pos2d = slot_pos.reshape(1, c)
    qpos_arr = jnp.asarray(q_pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               scale=scale, kv_steps=kv_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, j, *_: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, j, *_: (bb, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, j, *_: (bb, h, j, 0)),
            pl.BlockSpec((1, bk), lambda bb, h, j, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, j, *_: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qpos_arr, qg, k_cache, v_cache, pos2d)
    return out.reshape(b, hq, d)
