"""Jit'd public wrapper for decode attention (TPU kernel / CPU fallback)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.dispatch import dispatch


def decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                slot_pos: jax.Array, q_pos, *, window: int = 0,
                bk: int = 1024, force_kernel: bool = False) -> jax.Array:
    return dispatch(
        lambda interpret: decode_attention(q, k_cache, v_cache, slot_pos,
                                           q_pos, window=window, bk=bk,
                                           interpret=interpret),
        lambda: decode_attention_ref(q, k_cache, v_cache, slot_pos, q_pos,
                                     window=window),
        force_kernel=force_kernel)
