"""Pure-jnp oracle for single-token decode attention over a ring KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, slot_pos: jax.Array,
                         q_pos, *, window: int = 0,
                         scale: float | None = None) -> jax.Array:
    """q: (B, Hq, D); caches: (B, Hkv, C, D); slot_pos: (C,) absolute
    positions per slot (sentinel > q_pos for unwritten slots).
    Returns (B, Hq, D)."""
    b, hq, d = q.shape
    hkv, c = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    sc = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * sc
    ok = slot_pos <= q_pos
    if window:
        ok &= slot_pos > q_pos - window
    logits = jnp.where(ok[None, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
