"""Shared backend dispatch for the Pallas kernel wrappers.

Every kernel package exposes the same three-way split: compiled Pallas on
TPU, interpret-mode Pallas when explicitly forced on CPU (numerical tests),
and the pure-jnp reference otherwise (fast CPU path for examples).  The
pattern used to be copy-pasted across ``kernels/*/ops.py``; it lives here
once so a new kernel gets it for free.
"""

from __future__ import annotations

import contextlib
from typing import Callable

import jax

_FORCE = False


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernels_forced() -> bool:
    """True while inside a ``force_kernels()`` block."""
    return _FORCE


@contextlib.contextmanager
def force_kernels():
    """Route every ``dispatch`` through the interpret-mode kernel.

    The dispatch decision is taken at trace time, so cached jitted
    callables would silently keep their old backend choice; entering and
    leaving the block clears JAX's compilation caches to force a retrace.
    Test-scoped by design — don't wrap a serving loop in this.
    """
    global _FORCE
    prev = _FORCE
    _FORCE = True
    jax.clear_caches()
    try:
        yield
    finally:
        _FORCE = prev
        jax.clear_caches()


def dispatch(kernel_call: Callable[[bool], jax.Array],
             ref_call: Callable[[], jax.Array], *,
             force_kernel: bool = False) -> jax.Array:
    """Run ``kernel_call(interpret)`` on TPU (compiled) or when forced
    (interpret mode); otherwise the jnp oracle ``ref_call()``."""
    if on_tpu():
        return kernel_call(False)
    if force_kernel or _FORCE:
        return kernel_call(True)
    return ref_call()
