"""ELK-blocked matmul Pallas TPU kernel.

The chip-level ELK realization (DESIGN.md §3B): VMEM is the ICCA "on-chip
SRAM", HBM the "off-chip memory", and the Pallas grid pipeline is exactly
the paper's double buffer — the (bm, bn) output tile + (bm, bk)/(bk, bn)
operand tiles are the *execution space*; the pipeline's prefetched next
blocks are the *preload space*.  ``core/integration.vmem_plan()`` picks
(bm, bn, bk) by running the paper's cost-aware allocation against the VMEM
budget, trading larger K blocks (fewer accumulator flushes, more reuse)
against deeper HBM prefetch.

Grid is (M/bm, N/bn, K/bk) with the K axis innermost: the fp32 accumulator
lives in VMEM scratch across K steps and the output tile is written once —
one HBM write per tile, the ELK "execute-state" residency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the Mosaic params class TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def elk_matmul(x: jax.Array, y: jax.Array, *, bm: int = 256, bn: int = 256,
               bk: int = 512, interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N), fp32 accumulate, dtype-of-x output.

    Block sizes must divide the padded operand shapes; operands are padded
    up to block multiples (zero padding is exact for matmul)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)

    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, y)
    return out[:m, :n]
