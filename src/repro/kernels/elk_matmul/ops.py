"""Jit'd public wrapper for the ELK-blocked matmul.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
``interpret=True`` mode, executing the kernel body in Python for numerical
validation against ``ref.py``."""

from __future__ import annotations

import jax

from repro.kernels.dispatch import dispatch
from repro.kernels.elk_matmul.kernel import elk_matmul
from repro.kernels.elk_matmul.ref import matmul_ref


def matmul(x: jax.Array, y: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, force_kernel: bool = False) -> jax.Array:
    """Blocked matmul; Pallas on TPU, interpret-mode Pallas when forced on
    CPU (tests), jnp oracle otherwise (fast CPU path for examples)."""
    return dispatch(
        lambda interpret: elk_matmul(x, y, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret),
        lambda: matmul_ref(x, y),
        force_kernel=force_kernel)
