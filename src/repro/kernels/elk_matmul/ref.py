"""Pure-jnp oracle for the ELK-blocked matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array,
               out_dtype=None) -> jax.Array:
    """(M, K) @ (K, N) with fp32 accumulation."""
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)
