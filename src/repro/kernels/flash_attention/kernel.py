"""Flash attention Pallas TPU kernel (causal, GQA, sliding-window).

ELK view (DESIGN.md §3B): KV blocks stream HBM->VMEM exactly like the
paper's operator preloads — the (bq, D) query tile and (bq, bk) score tile
are the *execution space*, the in-flight KV blocks the *preload space*.
The online-softmax running (max, sum) carry is what lets the KV "preload"
depth stay O(1) in sequence length.

Grid: (B, Hq, S/bq, S/bk) with the KV axis innermost.  Causal + window
pruning is done twice: whole blocks that cannot contribute are masked via
a cheap block-level predicate (the index map still walks them — Mosaic
skips the body under ``pl.when``), and the diagonal blocks get an exact
element mask from iota.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the Mosaic params class TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  kv_steps: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq                    # first q position of this block
    k_lo = kj * bk
    # block-level prune: any (q, k) pair in range?
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) -> (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = d ** -0.5
    kv_steps = s // bk

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=scale, kv_steps=kv_steps)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, s // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, j, g=g: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, j, g=g: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, i, j: (bb, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
