"""Jit'd public wrapper for flash attention (TPU kernel / CPU fallback)."""

from __future__ import annotations

import jax

from repro.kernels.dispatch import dispatch
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              bq: int = 512, bk: int = 512,
              force_kernel: bool = False) -> jax.Array:
    return dispatch(
        lambda interpret: flash_attention(q, k, v, causal=causal,
                                          window=window, bq=bq, bk=bk,
                                          interpret=interpret),
        lambda: mha_ref(q, k, v, causal=causal, window=window),
        force_kernel=force_kernel)
