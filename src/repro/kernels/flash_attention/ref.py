"""Pure-jnp oracle for blockwise (flash) attention: causal / GQA / SWA."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, window: int = 0,
            scale: float | None = None) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) -> (B, Hq, S, D).

    fp32 softmax; GQA via head-group folding; ``window`` > 0 applies
    sliding-window attention of that width."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    sc = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, s, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * sc
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    logits = jnp.where(ok, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)
