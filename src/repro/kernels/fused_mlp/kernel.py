"""Fused MLP (up-proj -> activation -> down-proj) Pallas TPU kernel.

The kernel-level realization of the inter-core fusion pass
(``core/fusion.py``, DESIGN.md §8): the whole MLP chain runs in ONE grid,
so the intermediate activation ``h = act(x @ w_up)`` never round-trips
through HBM — it lives in VMEM for exactly one grid step, the Pallas
analogue of the ICCA chip staging the intermediate in aggregate SRAM.

Grid is (M/bm, FF/bf) with the FF axis innermost ("arbitrary"): each step
computes a (bm, bf) slab of the intermediate, applies the activation
register-resident, and accumulates its down-projection into a persistent
fp32 (bm, d_out) VMEM scratch.  Both weight matrices stream through VMEM
exactly once per M block — the "one HBM pass for both weights" the fused
cost curve prices.

Variants cover every fusable chain the pass emits: plain MLP (optional
fc biases, OPT-style), GLU (separate gate matrix, LLaMA-style), and the
RWKV channel-mix / MoE shared-expert forms (structurally plain/GLU).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_mlp.ref import _ACT

# jax < 0.5 names the Mosaic params class TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _make_kernel(act_fn, gated: bool, bias: bool):
    def kernel(*refs):
        x_ref, wu_ref = refs[0], refs[1]
        i = 2
        wg_ref = None
        if gated:
            wg_ref, i = refs[i], i + 1
        wd_ref, i = refs[i], i + 1
        bu_ref = bd_ref = None
        if bias:
            bu_ref, bd_ref, i = refs[i], refs[i + 1], i + 2
        o_ref, acc_ref = refs[i], refs[i + 1]

        @pl.when(pl.program_id(1) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        h = jnp.dot(x_ref[...], wu_ref[...],
                    preferred_element_type=jnp.float32)
        if bias:
            h = h + bu_ref[...].astype(jnp.float32)
        if gated:
            g = jnp.dot(x_ref[...], wg_ref[...],
                        preferred_element_type=jnp.float32)
            h = act_fn(g) * h
        else:
            h = act_fn(h)
        acc_ref[...] += jnp.dot(h.astype(o_ref.dtype), wd_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _flush():
            out = acc_ref[...]
            if bias:
                out = out + bd_ref[...].astype(jnp.float32)
            o_ref[...] = out.astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("act", "bm", "bf", "interpret"))
def fused_mlp_kernel(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                     w_gate=None, b_up=None, b_down=None, *,
                     act: str = "silu", bm: int = 128, bf: int = 512,
                     interpret: bool = False) -> jax.Array:
    """act(x @ w_up [+ b_up]) [* gate] @ w_down [+ b_down] in one grid.

    ``x``: (..., d); ``w_up``/``w_gate``: (d, ff); ``w_down``: (ff, d_out).
    fp32 accumulation throughout; the intermediate slab is cast back to the
    activation dtype before the down-projection (matching the composed
    per-matmul reference).  Operands are zero-padded up to block multiples
    — exact for every supported activation because padded ``w_down`` rows
    are zero."""
    lead, d = x.shape[:-1], x.shape[-1]
    m = math.prod(lead)
    ff, dout = w_up.shape[1], w_down.shape[1]
    assert w_down.shape[0] == ff, (w_up.shape, w_down.shape)
    if m == 0:
        return jnp.zeros((*lead, dout), x.dtype)
    x2 = x.reshape(m, d)
    gated, bias = w_gate is not None, b_up is not None
    bm, bf = min(bm, m), min(bf, ff)
    mp = -(-m // bm) * bm
    ffp = -(-ff // bf) * bf
    if mp != m:
        x2 = jnp.pad(x2, ((0, mp - m), (0, 0)))
    if ffp != ff:
        w_up = jnp.pad(w_up, ((0, 0), (0, ffp - ff)))
        w_down = jnp.pad(w_down, ((0, ffp - ff), (0, 0)))
        if gated:
            w_gate = jnp.pad(w_gate, ((0, 0), (0, ffp - ff)))
        if bias:
            b_up = jnp.pad(b_up, (0, ffp - ff))

    args = [x2, w_up]
    in_specs = [pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                pl.BlockSpec((d, bf), lambda i, j: (0, j))]
    if gated:
        args.append(w_gate)
        in_specs.append(pl.BlockSpec((d, bf), lambda i, j: (0, j)))
    args.append(w_down)
    in_specs.append(pl.BlockSpec((bf, dout), lambda i, j: (j, 0)))
    if bias:
        args += [b_up.reshape(1, ffp), b_down.reshape(1, dout)]
        in_specs += [pl.BlockSpec((1, bf), lambda i, j: (0, j)),
                     pl.BlockSpec((1, dout), lambda i, j: (0, 0))]

    out = pl.pallas_call(
        _make_kernel(_ACT[act], gated, bias),
        grid=(mp // bm, ffp // bf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, dout), lambda i, j: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, dout), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((mp, dout), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out[:m].reshape(*lead, dout)
