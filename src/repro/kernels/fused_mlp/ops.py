"""Jit'd public wrapper for the fused MLP (TPU kernel / CPU fallback)."""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.dispatch import dispatch
from repro.kernels.fused_mlp.kernel import fused_mlp_kernel
from repro.kernels.fused_mlp.ref import fused_mlp_ref


def fused_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
              w_gate: Optional[jax.Array] = None,
              b_up: Optional[jax.Array] = None,
              b_down: Optional[jax.Array] = None,
              act: str = "silu", bm: int = 128, bf: int = 512,
              force_kernel: bool = False) -> jax.Array:
    """up-proj -> activation -> down-proj without storing the intermediate.

    GLU when ``w_gate`` is given, plain MLP (optional fc biases) otherwise.
    """
    return dispatch(
        lambda interpret: fused_mlp_kernel(x, w_up, w_down, w_gate, b_up,
                                           b_down, act=act, bm=bm, bf=bf,
                                           interpret=interpret),
        lambda: fused_mlp_ref(x, w_up, w_down, w_gate=w_gate, b_up=b_up,
                              b_down=b_down, act=act),
        force_kernel=force_kernel)
