"""Pure-jnp oracle for the fused MLP kernel.

``fused_mlp_ref`` is the exact einsum composition ``models.layers.mlp``
used before the fused runtime path existed, so the default CPU dispatch
is bit-identical to the historical model output; the kernel parity tests
instead compare against ``composed_ref`` (matmul_ref + activation +
matmul_ref), the per-matmul fp32-accumulate oracle the other kernels use.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

_ACT = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu}


def fused_mlp_ref(x: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
                  w_gate: Optional[jax.Array] = None,
                  b_up: Optional[jax.Array] = None,
                  b_down: Optional[jax.Array] = None,
                  act: str = "silu") -> jax.Array:
    a = _ACT[act]
    if w_gate is not None:
        gate = jnp.einsum("...d,df->...f", x, w_gate)
        up = jnp.einsum("...d,df->...f", x, w_up)
        return jnp.einsum("...d,df->...f", a(gate) * up, w_down)
    h = jnp.einsum("...d,df->...f", x, w_up)
    if b_up is not None:
        h = h + b_up.astype(h.dtype)
    h = a(h)
    out = jnp.einsum("...d,df->...f", h, w_down)
    if b_down is not None:
        out = out + b_down.astype(out.dtype)
    return out


def composed_ref(x: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
                 w_gate: Optional[jax.Array] = None,
                 b_up: Optional[jax.Array] = None,
                 b_down: Optional[jax.Array] = None,
                 act: str = "silu") -> jax.Array:
    """matmul_ref + activation + matmul_ref — the kernel parity oracle."""
    from repro.kernels.elk_matmul.ref import matmul_ref
    a = _ACT[act]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if w_gate is not None:
        h = a(matmul_ref(x2, w_gate)) * matmul_ref(x2, w_up)
    else:
        h = matmul_ref(x2, w_up)
        if b_up is not None:
            h = h + b_up.astype(h.dtype)
        h = a(h)
    out = matmul_ref(h.astype(x.dtype), w_down)
    if b_down is not None:
        out = out + b_down.astype(out.dtype)
    return out.reshape(*lead, w_down.shape[-1])
