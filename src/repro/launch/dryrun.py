import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes below need 512 placeholder
devices.  Everything else imports after.

For each cell this driver:
  1. builds the step function + ShapeDtypeStruct inputs (``launch.specs``),
  2. ``jit(...).lower(...).compile()`` under the production mesh,
  3. records ``memory_analysis()`` (fits-in-HBM evidence),
     ``cost_analysis()`` (FLOPs/bytes) and the parsed collective schedule
     (``launch.hlo_analysis``) into ``experiments/dryrun/<cell>.json``.

Resumable: cells with an existing JSON are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_14b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import memory_summary, roofline_from_compiled
from repro.launch.mesh import make_production_mesh, mesh_num_devices
from repro.launch.specs import SHAPES, build_cell, eligible

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_path(arch: str, shape: str, mesh_name: str, mode: str) -> str:
    tag = f"{arch}__{shape}__{mesh_name}" + ("" if mode == "elk"
                                             else f"__{mode}")
    return os.path.join(OUT_DIR, tag + ".json")


def _plan_prefetch_depth(cfg, shape: str) -> int:
    """Prefetch depth from the ELK scheduler (cached plan, DESIGN.md §2):
    repeat cells for the same arch/shape reuse one compile."""
    from repro.core.integration import pod_plan
    case = SHAPES[shape]
    knobs = pod_plan(cfg, batch=case.batch, seq=case.seq, phase="decode")
    return max(knobs.prefetch_depth, 1)


def run_cell(arch: str, shape: str, mesh_name: str, *, mode: str = "elk",
             prefetch_depth: int = 2, force: bool = False,
             extra_tag: str = "") -> dict:
    """``prefetch_depth=0`` asks the ELK scheduler (via the plan cache)."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = cell_path(arch, shape, mesh_name, mode)
    if extra_tag:
        path = path.replace(".json", f"__{extra_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, why = eligible(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "mode": mode}
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    if prefetch_depth <= 0:
        prefetch_depth = _plan_prefetch_depth(cfg, shape)

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh_num_devices(mesh)
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh, mode=mode,
                          prefetch_depth=prefetch_depth)
        with mesh:
            lowered = cell.fn.lower(*[a for a in cell.args])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = memory_summary(compiled)
        rf, colls = roofline_from_compiled(
            compiled, cell.meta["model_flops"], n_chips)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=mem,
            fits_16gb=mem.get("total_hbm_bytes", 0) <= 16 * 1024 ** 3,
            roofline=rf.to_dict(),
            collectives={"counts": colls.counts,
                         "by_kind_bytes": colls.by_kind_bytes,
                         "result_bytes": colls.result_bytes},
            meta=cell.meta,
        )
        print(f"[ok] {arch:28s} {shape:12s} {mesh_name:6s} "
              f"compile={t_compile:6.1f}s "
              f"hbm/dev={mem.get('total_hbm_bytes', 0)/2**30:7.2f}GiB "
              f"dom={rf.dominant:10s} bound={rf.bound_s*1e3:9.3f}ms "
              f"roofline={rf.roofline_fraction:6.1%}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERR] {arch} {shape} {mesh_name}: {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _recurrence_correction(cfg, batch: int, seq: int, phase: str,
                           train_mult: float = 4.0) -> tuple[float, float]:
    """Analytic FLOPs/bytes for time-recurrent ops (wkv / ssm scans): their
    ``lax.scan`` over the sequence is counted once by cost_analysis even in
    the unrolled accounting variants.  Returns (flops, bytes) to add.
    Train multiplies by ~4 (fwd + remat-recompute + bwd)."""
    if not (cfg.rwkv or cfg.hybrid_parallel_ssm):
        return 0.0, 0.0
    from repro.core.graph import build_graph
    g = build_graph(cfg, batch=batch, seq=seq,
                    phase="train_fwd" if phase == "train" else phase)
    fl = by = 0.0
    for op in g.ops:
        if op.name.endswith(".wkv") or op.name.endswith(".ssm_scan"):
            fl += op.flops
            by += op.hbm_bytes + op.act_bytes + op.out_bytes
    mult = train_mult if phase == "train" else 1.0
    return fl * mult, by * mult


def _score_bytes(cfg, case) -> float:
    """Analytic HBM bytes of materialized attention score/softmax tensors
    (what the Pallas flash kernel keeps in VMEM).  fp32 scores, one write +
    one read each for scores and probs; x4 for train (fwd + remat + bwd)."""
    from repro.core.graph import build_graph
    g = build_graph(cfg, batch=case.batch, seq=case.seq,
                    phase="train_fwd" if case.kind == "train"
                    else case.kind)
    total = 0.0
    for op in g.ops:
        nm = op.name.rsplit(".", 1)[-1]
        if nm in ("score", "softmax", "xscore", "xsoftmax"):
            total += op.out_bytes * 2 * 2.0      # fp32, write+read
    return total * (4.0 if case.kind == "train" else 1.0)


def run_cell_accounting(arch: str, shape: str, mesh_name: str, *,
                        mode: str = "elk", prefetch_depth: int = 2,
                        force: bool = False) -> dict:
    """Roofline accounting for one cell: two reduced-L *unrolled* compiles,
    linear extrapolation in the block count, grad-accum scaling for train.

    cost_analysis counts a while/scan body once; the production compile is
    therefore only used for memory fit + schedule, and this accounting pass
    produces the §Roofline terms."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = cell_path(arch, shape, mesh_name, mode).replace(
        ".json", "__acct.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    from repro.launch.hlo_analysis import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                           Roofline, parse_collectives)
    from repro.models.transformer import block_structure
    cfg = get_config(arch)
    case = SHAPES[shape]
    ok, why = eligible(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "mode": mode,
           "kind": "accounting"}
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh_num_devices(mesh)
    prefix, period, n_blocks_full = block_structure(cfg)

    is_train = case.kind == "train"
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    # mirror build_cell's default microbatching exactly
    ga_full = max(1, case.batch // (dp * 8)) if is_train else 1
    batch_acct = case.batch // ga_full if is_train else None

    # reduced-L variants: prefix + 1 and + 3 periods (or full if smaller)
    b1 = min(1, n_blocks_full)
    b2 = min(3, n_blocks_full)
    variants = sorted({b1, b2})

    try:
        totals = []
        for nb in variants:
            L = prefix + nb * period
            cell = build_cell(arch, shape, mesh, mode=mode,
                              prefetch_depth=prefetch_depth,
                              num_layers_override=L, unroll=True,
                              grad_accum=1 if is_train else None,
                              batch_override=batch_acct)
            with mesh:
                compiled = cell.fn.lower(*cell.args).compile()
            ca = compiled.cost_analysis() or {}
            colls = parse_collectives(compiled.as_text())
            totals.append({
                "n_blocks": nb,
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": colls.wire_bytes,
                "counts": colls.counts,
            })

        def extrap(key: str) -> float:
            if len(totals) == 1 or totals[0]["n_blocks"] == totals[-1]["n_blocks"]:
                return totals[-1][key]
            a, b = totals[0], totals[-1]
            slope = (b[key] - a[key]) / (b["n_blocks"] - a["n_blocks"])
            return max(b[key] + slope * (n_blocks_full - b["n_blocks"]), 0.0)

        flops = extrap("flops")
        byts = extrap("bytes")
        wire = extrap("wire")

        if is_train:
            # accounting step = 1 microbatch fwd/bwd + full optimizer;
            # production = ga x fwd/bwd + optimizer.  Optimizer cost is
            # estimated analytically and rescaled (per-chip).
            p_total = cfg.param_count()
            sdt = 2 if p_total > 1e11 else 4
            opt_flops = 12.0 * p_total / n_chips
            opt_bytes = (8.0 + 4.0 * sdt) * p_total / n_chips
            fb_flops = max(flops - opt_flops, 0.0)
            fb_bytes = max(byts - opt_bytes, 0.0)
            flops = ga_full * fb_flops + opt_flops
            byts = ga_full * fb_bytes + opt_bytes
            wire = ga_full * wire          # grad reduce happens /microbatch

        # time-recurrence analytic correction (per-chip share)
        cf, cb = _recurrence_correction(cfg, case.batch, case.seq, case.kind)
        flops += cf / n_chips
        byts += cb / n_chips

        # flash-kernel adjustment: the XLA lowering materializes attention
        # score matrices to HBM; the deployed TPU path streams them through
        # VMEM (kernels/flash_attention).  Report both terms.
        flash_save = _score_bytes(cfg, case) / n_chips
        byts_flash = max(byts - flash_save, 0.0)

        from repro.launch.specs import model_flops
        rf = Roofline(
            compute_s=flops / PEAK_FLOPS,
            memory_s=byts / HBM_BW,
            collective_s=wire / LINK_BW,
            hlo_flops_per_chip=flops,
            hlo_bytes_per_chip=byts,
            wire_bytes_per_chip=wire,
            model_flops=model_flops(cfg, case),
            num_chips=n_chips,
        )
        rf_flash = Roofline(
            compute_s=rf.compute_s, memory_s=byts_flash / HBM_BW,
            collective_s=rf.collective_s,
            hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts_flash,
            wire_bytes_per_chip=wire,
            model_flops=rf.model_flops, num_chips=n_chips)
        rec.update(status="ok", roofline=rf.to_dict(),
                   roofline_flash=rf_flash.to_dict(), variants=totals,
                   grad_accum=ga_full,
                   recurrence_correction={"flops": cf, "bytes": cb},
                   flash_saved_bytes=flash_save)
        print(f"[acct] {arch:28s} {shape:12s} {mesh_name:6s} "
              f"dom={rf_flash.dominant:10s} "
              f"bound={rf_flash.bound_s*1e3:9.3f}ms "
              f"roofline={rf_flash.roofline_fraction:6.1%} "
              f"useful={rf.useful_flops_ratio:5.1%}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERR acct] {arch} {shape} {mesh_name}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--mode", choices=["elk", "gspmd"], default="elk")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="0 = derive per cell from the ELK scheduler "
                         "(cached across cells)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="alias for --arch all --shape all --mesh both")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" or args.all else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" or args.all else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" or args.all
              else [args.mesh])

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_name, mode=args.mode,
                               prefetch_depth=args.prefetch_depth,
                               force=args.force)
                st = rec.get("status")
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
