"""Post-compile HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` has FLOPs and bytes but no collective volumes, so the
collective term is parsed from the SPMD-partitioned module text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
result shape is converted to per-chip wire bytes with the standard ring
formulas (group size from ``replica_groups``).

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^)\s]*(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_TILED_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(decl: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(decl):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_TILED_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


@dataclasses.dataclass
class CollectiveStats:
    """Per-chip collective traffic, ring-model wire bytes."""
    wire_bytes: float = 0.0          # bytes crossing this chip's links
    result_bytes: float = 0.0        # raw sum of collective result shapes
    counts: dict = dataclasses.field(default_factory=dict)
    by_kind_bytes: dict = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats(counts=Counter(), by_kind_bytes=Counter())
    seen_starts = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # avoid double counting async start/done pairs: 'done' lines don't
        # match (they call the started op), starts counted once
        decl, kind = m.group(1), m.group(2)
        b = _shape_bytes(decl)
        g = _group_size(line)
        if kind == "all-gather":
            # result is gathered; each chip sends its 1/g and receives the
            # rest: wire = result * (g-1)/g
            wire = b * (g - 1) / g
        elif kind == "all-reduce":
            # ring all-reduce = reduce-scatter + all-gather on the shard
            wire = 2 * b * (g - 1) / g
        elif kind == "reduce-scatter":
            # result is the scattered shard; input was g*b
            wire = b * (g - 1)
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = b
        stats.counts[kind] += 1
        stats.by_kind_bytes[kind] += wire
        stats.wire_bytes += wire
        stats.result_bytes += b
    stats.counts = dict(stats.counts)
    stats.by_kind_bytes = dict(stats.by_kind_bytes)
    return stats


@dataclasses.dataclass
class Roofline:
    """The three §Roofline terms, in seconds (per step)."""
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops: float
    num_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste check."""
        tot = self.hlo_flops_per_chip * self.num_chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at
        its bound: MODEL_FLOPS / (chips * peak * bound_s)."""
        denom = self.num_chips * PEAK_FLOPS * self.bound_s
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "bound_s": self.bound_s,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "num_chips": self.num_chips,
        }


def roofline_from_compiled(compiled, model_flops: float,
                           num_chips: int) -> tuple[Roofline, CollectiveStats]:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))            # per-chip (SPMD module)
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    rf = Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=colls.wire_bytes / LINK_BW,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=bytes_acc,
        wire_bytes_per_chip=colls.wire_bytes,
        model_flops=model_flops,
        num_chips=num_chips,
    )
    return rf, colls


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    out = {f: int(getattr(ma, f, 0)) for f in fields}
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    return out
