"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization and only then calls these.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def _make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh, with explicit Auto axis types where the installed jax
    supports them (jax < 0.5 has neither AxisType nor the kwarg — its meshes
    are implicitly Auto)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod: a leading
    pod axis of pure data parallelism, (pod=2, data=16, model=16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Mesh over the locally visible devices (tests / CPU examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return _make_mesh((data, model), ("data", "model"))


def mesh_num_devices(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
