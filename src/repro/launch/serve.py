"""Serving launcher: batched generation with the ELK streaming engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --mode elk_stream --batch 4 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, canonical, get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.serve.engine import ServeConfig, ServeEngine, elk_serve_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ARCH_IDS} (dashed aliases ok)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="elk_stream",
                    choices=["gspmd", "elk_stream"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "int8"])
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="0 = ask the ELK scheduler (core.integration)")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())

    if args.prefetch_depth <= 0 and args.mode == "elk_stream":
        scfg = elk_serve_config(get_config(arch), batch=args.batch,
                                cache_capacity=args.cache,
                                kv_dtype=args.kv_dtype)
        print(f"ELK scheduler: prefetch_depth={scfg.prefetch_depth}")
    else:
        scfg = ServeConfig(
            batch=args.batch, cache_capacity=args.cache, mode=args.mode,
            prefetch_depth=max(args.prefetch_depth, 1),
            kv_dtype=args.kv_dtype)

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, mesh, params, scfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    t0 = time.perf_counter()
    out = eng.generate(prompts, steps=args.steps)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"generated {args.steps} tokens x {args.batch} requests in "
          f"{dt:.2f}s ({args.steps*args.batch/dt:.1f} tok/s); "
          f"sample: {out[0, -args.steps:].tolist()}")


if __name__ == "__main__":
    main()
