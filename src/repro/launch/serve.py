"""Serving launcher: batched generation with the ELK streaming engine.

Lock-step batch:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --mode elk_stream --batch 4 --steps 16

Continuous batching over a mixed-length request trace (tok/s + request
latency percentiles, optionally against the static-batching baseline):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --trace 16 --compare-static
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCH_IDS, canonical, get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.serve.engine import ServeConfig, ServeEngine, elk_serve_config


def _run_trace(eng: ServeEngine, args, vocab: int) -> dict:
    from repro.serve.batcher import (ContinuousBatcher, make_trace,
                                     run_static_trace, summarize)

    trace = make_trace(args.trace, vocab_size=vocab,
                       arrival_spacing_s=args.arrival_spacing,
                       seed=args.trace_seed, burst=args.burst,
                       sys_prompt_len=args.sys_prompt_len,
                       sys_prompt_frac=args.sys_prompt_frac)
    # warm the compile caches so the numbers are steady-state serving
    warm = make_trace(min(4, args.trace), vocab_size=vocab,
                      seed=args.trace_seed + 1)
    ContinuousBatcher(eng).run(warm)

    prefix_store = None
    if args.prefix_cache_mb > 0:
        from repro.serve.prefix import PrefixStore
        prefix_store = PrefixStore(args.prefix_cache_mb << 20)
    oversub = args.oversub if args.oversub > 0 else None
    bat = ContinuousBatcher(eng, oversub=oversub, prefix_store=prefix_store)
    t0 = time.perf_counter()
    completions = bat.run(trace)
    stats = {"continuous": summarize(completions,
                                     time.perf_counter() - t0)}
    stats["continuous"]["oversub"] = round(bat.oversub, 3)
    stats["continuous"]["spill_events"] = len(bat.spill_events)
    stats["continuous"]["planned_spill_s"] = round(bat.planned_spill_s, 6)
    if bat.prefix is not None:
        stats["continuous"]["prefix_hits"] = bat.prefix_hits
        stats["continuous"]["prefix_tokens_saved"] = bat.prefix_tokens_saved
    order = [c.rid for c in completions]
    print(f"continuous: {stats['continuous']}  finish order: {order}")

    if args.compare_static:
        run_static_trace(eng, warm)
        t0 = time.perf_counter()
        static = run_static_trace(eng, trace)
        stats["static"] = summarize(static, time.perf_counter() - t0)
        print(f"static:     {stats['static']}")

    if args.fleet > 0:
        stats["fleet"] = _run_fleet(eng, args, vocab)
        print(f"fleet:      {stats['fleet']}")
    return stats


def _run_fleet(eng: ServeEngine, args, vocab: int) -> dict:
    """Serve the same trace on an ``--fleet N`` pod fleet over the virtual
    clock (deterministic scheduling deltas, not wall time): N mixed
    replicas by default, or ``--disagg`` 1 prefill + N-1 decode pods with
    router-priced migrations; ``--ttft-slo-ms`` arms shedding."""
    import dataclasses

    from repro.serve.batcher import make_trace
    from repro.serve.engine import PREFILL_SAT
    from repro.serve.fleet import FleetPod, FleetRouter, PodCosts

    def pod(role):
        # mirror elk_serve_config's role sizing on the launcher's scfg
        chunk = eng.scfg.prefill_chunk
        if role == "prefill":
            chunk = min(PREFILL_SAT, eng.scfg.cache_capacity)
        elif role == "decode":
            chunk = min(16, eng.scfg.cache_capacity)
        scfg = dataclasses.replace(eng.scfg, prefill_chunk=chunk)
        return FleetPod(ServeEngine(eng.cfg, eng.mesh, eng.params, scfg),
                        role, costs=PodCosts.from_serve_config(scfg))

    roles = (["prefill"] + ["decode"] * (args.fleet - 1)
             if args.disagg and args.fleet > 1
             else ["mixed"] * args.fleet)
    router = FleetRouter([pod(r) for r in roles],
                         ttft_slo_s=args.ttft_slo_ms * 1e-3)
    router.run(make_trace(args.trace, vocab_size=vocab,
                          arrival_spacing_s=args.arrival_spacing,
                          seed=args.trace_seed, burst=args.burst,
                          sys_prompt_len=args.sys_prompt_len,
                          sys_prompt_frac=args.sys_prompt_frac))
    out = router.summary()
    out["disagg"] = bool(args.disagg and args.fleet > 1)
    out["ttft_slo_ms"] = args.ttft_slo_ms
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ARCH_IDS} (dashed aliases ok)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="elk_stream",
                    choices=["gspmd", "elk_stream"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "int8"])
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="0 = ask the ELK scheduler (core.integration)")
    ap.add_argument("--pipeline-pod", type=int, default=0, metavar="GROUPS",
                    help="plan the pod across GROUPS chip islands with the "
                         "joint hybrid search (cuts x tensor width x "
                         "replicas x microbatch, DESIGN.md §9; never worse "
                         "than pure pipeline stages) and size admission "
                         "from the steady-state interval (0 = flat pod)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="serve N mixed-length requests with continuous "
                         "batching instead of one lock-step batch")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--arrival-spacing", type=float, default=0.0,
                    help="seconds between request arrivals in --trace mode")
    ap.add_argument("--burst", type=int, default=1,
                    help="bursty arrivals: requests land in groups of this "
                         "size sharing one arrival time")
    ap.add_argument("--sys-prompt-len", type=int, default=0,
                    help="length of a shared system prompt prepended to "
                         "--sys-prompt-frac of the trace (prefix reuse)")
    ap.add_argument("--sys-prompt-frac", type=float, default=0.0)
    ap.add_argument("--oversub", type=float, default=0.0,
                    help="admission multiplier K over the physical slots "
                         "(0 = take K from the plan; needs a finite "
                         "backing tier, e.g. --stacked-gb)")
    ap.add_argument("--prefix-cache-mb", type=int, default=0,
                    help="prefix-KV store budget in MB (0 = plan-sized, "
                         "off unless the pod funds it)")
    ap.add_argument("--stacked-gb", type=float, default=0.0,
                    help="plan against an SRAM-only pod with this much "
                         "stacked DRAM (all-finite hierarchy: enables KV "
                         "offload + oversubscription, DESIGN.md §11)")
    ap.add_argument("--compare-static", action="store_true",
                    help="also run the static-batching baseline on the "
                         "same trace")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="also serve the trace on an N-pod fleet behind "
                         "the SLO-aware router, ticked on the virtual "
                         "clock (DESIGN.md §12)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregate the --fleet pods: 1 prefill pod + "
                         "N-1 decode pods with router-priced KV "
                         "migrations (default: N mixed replicas)")
    ap.add_argument("--ttft-slo-ms", type=float, default=0.0,
                    help="shed fleet requests whose predicted TTFT "
                         "exceeds this target (0 = admit everything)")
    ap.add_argument("--json-out", default="",
                    help="write --trace stats to this JSON file")
    args = ap.parse_args()

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())

    if args.prefetch_depth <= 0 and args.mode == "elk_stream":
        # plan against the config actually served: a smoke engine must not
        # run a prefetch depth chosen for the full-size model
        pod = None
        if args.pipeline_pod > 0:
            from repro.chip.config import tpu_v5e_pod_hier
            pod = tpu_v5e_pod_hier(groups=args.pipeline_pod)
        elif args.stacked_gb > 0:
            from repro.chip.config import GB, ipu_mk2
            pod = ipu_mk2().with_stacked_dram(int(args.stacked_gb * GB))
        scfg = elk_serve_config(cfg, batch=args.batch,
                                cache_capacity=args.cache,
                                kv_dtype=args.kv_dtype,
                                pipeline=args.pipeline_pod > 0, pod=pod)
        msg = (f"ELK scheduler: prefetch_depth={scfg.prefetch_depth} "
               f"prefill_chunk={scfg.prefill_chunk}")
        if scfg.steady_interval_s:
            msg += (f" steady_interval="
                    f"{scfg.steady_interval_s * 1e3:.3f}ms")
        if scfg.oversub > 1.0:
            msg += (f" oversub K={scfg.oversub:.2f} "
                    f"slot_spill={scfg.slot_spill_s * 1e6:.1f}us "
                    f"prefix_cache={scfg.prefix_cache_bytes >> 20}MB")
        print(msg)
    else:
        scfg = ServeConfig(
            batch=args.batch, cache_capacity=args.cache, mode=args.mode,
            prefetch_depth=max(args.prefetch_depth, 1),
            kv_dtype=args.kv_dtype)

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, mesh, params, scfg)

    if args.trace > 0:
        stats = _run_trace(eng, args, cfg.vocab_size)
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(stats, f, indent=1)
            print(f"wrote {args.json_out}")
        return

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    t0 = time.perf_counter()
    out = eng.generate(prompts, steps=args.steps)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"generated {args.steps} tokens x {args.batch} requests in "
          f"{dt:.2f}s ({args.steps*args.batch/dt:.1f} tok/s); "
          f"sample: {out[0, -args.steps:].tolist()}")


if __name__ == "__main__":
    main()
