"""Dry-run cell construction: (arch x shape x mesh) -> lowerable closure.

``input_specs`` provides ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero device allocation.  ``build_cell``
returns the jit-able step function plus fully pinned in/out shardings for
one assignment cell:

* ``train_4k``      lowers ``train_step``   (microbatched fwd+bwd+AdamW)
* ``prefill_32k``   lowers ``prefill``      (prompt pass filling the cache)
* ``decode_32k``    lowers ``serve_step``   (one token, 32k KV cache)
* ``long_500k``     lowers ``serve_step``   (one token, 512k context;
  sub-quadratic archs only — SWA ring / SSM state keeps it O(window))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import (batch_axes, batch_shardings,
                                        cache_shardings, param_shardings,
                                        replicated)
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524_288, 1),
}


def eligible(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full attention at 512k context: O(L^2) attention "
                       "and O(L) bf16 KV exceed any replica HBM budget "
                       "(assignment rule: run for SSM/hybrid/SWA only)")
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders
# ---------------------------------------------------------------------------

def params_struct(cfg: ModelConfig, decode_positions: int = 0):
    return jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg,
                                decode_positions=decode_positions))


def input_specs(cfg: ModelConfig, case: ShapeCase) -> dict:
    """Model-input stand-ins for one shape case (tokens/labels or serving
    tensors), frontend stubs included."""
    b, s = case.batch, case.seq
    if case.kind == "train":
        out = {"tokens": SDS((b, s), jnp.int32),
               "labels": SDS((b, s), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["embeds"] = SDS((b, cfg.vision_patches, cfg.d_model),
                                jnp.bfloat16)
        if cfg.frontend == "audio_stub":
            out["enc_embeds"] = SDS((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
        return out
    if case.kind == "prefill":
        out = {"tokens": SDS((b, s), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["embeds"] = SDS((b, cfg.vision_patches, cfg.d_model),
                                jnp.bfloat16)
        if cfg.frontend == "audio_stub":
            out["enc_embeds"] = SDS((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
        return out
    return {"token": SDS((b,), jnp.int32)}


def cache_capacity(cfg: ModelConfig, case: ShapeCase) -> int:
    cap = case.seq
    if cfg.sliding_window and cfg.swa_layers == "all":
        cap = min(cap, cfg.sliding_window)
    return cap


def cache_struct(cfg: ModelConfig, case: ShapeCase, kv_dtype) -> Any:
    cap = cache_capacity(cfg, case)
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, tfm.CacheSpec(
            capacity=cap, batch=case.batch, kv_dtype=kv_dtype)))


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Any                    # jit-ready callable
    args: tuple                # ShapeDtypeStruct pytrees
    in_shardings: Any
    out_shardings: Any
    meta: dict                 # model_flops, params, notes


def _token_sharding(mesh: Mesh, batch: int):
    bp = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in bp])) if bp else 1
    return NamedSharding(mesh, P(bp) if batch % max(dp, 1) == 0 else P())


def model_flops(cfg: ModelConfig, case: ShapeCase) -> float:
    """The useful-FLOPs yardstick: 6*N*D train, 2*N_active*D inference."""
    n = cfg.active_param_count() if cfg.moe_experts else cfg.param_count()
    if case.kind == "train":
        return 6.0 * n * case.batch * case.seq
    if case.kind == "prefill":
        return 2.0 * n * case.batch * case.seq
    return 2.0 * n * case.batch          # one token per request


HBM_PER_CHIP = 16 * 1024 ** 3
_RESIDENT_BUDGET = 0.25 * HBM_PER_CHIP    # weights may take 1/4 of HBM


def needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """The ELK §4.3 capacity decision at pod level: weights stay resident
    (execute state, f=1) when the TP shard fits the budget; otherwise they
    are held sharded over data (preload state, f=1/k) and gather-ahead
    streamed.  Regathering weights every microbatch when they *could* be
    resident is pure waste — the first hillclimb iteration in
    EXPERIMENTS.md §Perf measures exactly this."""
    m = mesh.shape.get("model", 1)
    resident = cfg.param_count() * 2 / m       # bf16 TP shard
    return resident > _RESIDENT_BUDGET


def build_cell(arch: str, shape: str, mesh: Mesh, *,
               mode: str = "elk", prefetch_depth: int = 2,
               grad_accum: Optional[int] = None,
               num_layers_override: Optional[int] = None,
               batch_override: Optional[int] = None,
               unroll: bool = False) -> Cell:
    """mode: 'elk' = the framework defaults realizing the paper's technique
    (FSDP preload-state weights + gather-ahead streaming); 'gspmd' = plain
    TP-resident baseline.  The override/unroll knobs build the reduced-L
    *accounting variants* (XLA cost_analysis counts scan bodies once; the
    dry-run extrapolates unrolled reduced-L compiles linearly in L)."""
    cfg = get_config(arch)
    case = SHAPES[shape]
    if num_layers_override is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers_override)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll_scan=True)
    if batch_override is not None:
        case = dataclasses.replace(case, batch=batch_override)
    ok, why = eligible(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch},{shape}) ineligible: {why}")

    bp = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in bp])) if bp else 1
    meta = {"model_flops": model_flops(cfg, case),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "mode": mode, "dp": dp}

    if case.kind == "train":
        from repro.train.step import jit_train_step  # lazy: heavy imports
        # default microbatch: 8 sequences per data shard — deep-enough
        # accumulation for memory without per-microbatch grad-reduce waste
        ga = grad_accum or max(1, case.batch // (dp * 8))
        meta["grad_accum"] = ga
        # bf16 moments for the MoE giants (EXPERIMENTS §Dry-run memory note)
        sdt = "bfloat16" if cfg.param_count() > 1e11 else "float32"
        ocfg = adamw.AdamWConfig(state_dtype=sdt)
        meta["opt_state_dtype"] = sdt
        p_sds = params_struct(cfg)
        batch_sds = input_specs(cfg, case)
        fsdp = mode == "elk" and needs_fsdp(cfg, mesh)
        # dense models train in the 2D-FSDP layout: TP-16 activation
        # gathers cost ~30x the compute bound at 1M tokens/step
        # (EXPERIMENTS.md §Perf); MoE keeps EP-over-model + data-FSDP.
        layout = "fsdp2d" if (mode == "elk" and not cfg.moe_experts) \
            else "tp"
        meta["fsdp"] = fsdp
        meta["layout"] = layout
        jitted, sh = jit_train_step(cfg, mesh, ocfg, p_sds, batch_sds,
                                    grad_accum=ga, compression="none",
                                    fsdp=fsdp, layout=layout)
        opt_sds = jax.eval_shape(
            functools.partial(adamw.init_state, cfg=ocfg), p_sds)
        args = (p_sds, opt_sds, batch_sds, None)
        return Cell(arch, shape, jitted, args, None, None, meta)

    kv_dtype = jnp.int8 if shape == "decode_32k" and not cfg.rwkv \
        else jnp.bfloat16
    meta["kv_dtype"] = str(jnp.dtype(kv_dtype))
    dec_pos = case.seq + 8 if cfg.encoder_layers else 0
    p_sds = params_struct(cfg, decode_positions=dec_pos)
    fsdp = mode == "elk" and needs_fsdp(cfg, mesh)
    meta["fsdp"] = fsdp
    p_sh = param_shardings(p_sds, mesh, fsdp=fsdp)
    c_sds = cache_struct(cfg, case, kv_dtype)
    c_sh = cache_shardings(c_sds, mesh)

    if case.kind == "prefill":
        ins = input_specs(cfg, case)

        def prefill_fn(params, tokens, cache, embeds=None, enc_embeds=None):
            kw = {}
            if embeds is not None:
                kw["embeds"] = embeds
            if enc_embeds is not None:
                kw["enc_embeds"] = enc_embeds
            return tfm.prefill(params, cfg, tokens, cache, mesh=mesh, **kw)

        b_sh = batch_shardings(ins, mesh)
        v_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        in_sh = (p_sh, b_sh["tokens"], c_sh,
                 b_sh.get("embeds"), b_sh.get("enc_embeds"))
        out_sh = (NamedSharding(mesh, P(bp, None, v_ax)), c_sh)
        args = (p_sds, ins["tokens"], c_sds, ins.get("embeds"),
                ins.get("enc_embeds"))
        fn = jax.jit(prefill_fn, in_shardings=in_sh, out_shardings=out_sh)
        return Cell(arch, shape, fn, args, in_sh, out_sh, meta)

    # decode / long-context decode; streaming only pays off for weights
    # too large to stay resident (the same ELK capacity decision)
    tok_sh = _token_sharding(mesh, case.batch)
    if mode == "elk" and fsdp:
        from repro.serve.stream import streaming_decode_step

        def decode_fn(params, token, cache):
            return streaming_decode_step(params, cfg, token, cache,
                                         mesh=mesh, prefetch=prefetch_depth)
    else:
        def decode_fn(params, token, cache):
            return tfm.decode_step(params, cfg, token, cache, mesh=mesh)

    v_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logit_spec = P(bp, None, v_ax) if case.batch % max(dp, 1) == 0 \
        else P(None, None, v_ax)
    in_sh = (p_sh, tok_sh, c_sh)
    out_sh = (NamedSharding(mesh, logit_spec), c_sh)
    ins = input_specs(cfg, case)
    args = (p_sds, ins["token"], c_sds)
    fn = jax.jit(decode_fn, in_shardings=in_sh, out_shardings=out_sh)
    return Cell(arch, shape, fn, args, in_sh, out_sh, meta)
