"""Training launcher.

Smoke-scale on this CPU container (``--smoke``), production lowering via
``--dry-run`` (which defers to ``launch.dryrun``), and real-device runs on
a TPU slice (same code path, jax picks up the TPU topology).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 50 --workdir /tmp/run1
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import ARCH_IDS, canonical, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ARCH_IDS} (dashed aliases ok)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config runnable on CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) mesh (needs 256 devices)")
    args = ap.parse_args()

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())

    dcfg = DataConfig(batch=args.batch, seq=args.seq,
                      vocab_size=cfg.vocab_size)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                       total_steps=args.steps)
    tcfg = TrainerConfig(workdir=args.workdir, total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         grad_accum=args.grad_accum,
                         compression=args.compression, fsdp=args.fsdp)
    os.makedirs(args.workdir, exist_ok=True)
    trainer = Trainer(cfg, dcfg, ocfg, tcfg, mesh)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) on "
          f"{len(jax.devices())} device(s), resuming from step "
          f"{trainer.step}")
    log = trainer.run()
    out = os.path.join(args.workdir, "metrics.json")
    with open(out, "w") as f:
        json.dump(log, f, indent=1)
    print(f"final loss {log[-1]['loss']:.4f} "
          f"({log[-1]['step_time']*1e3:.0f} ms/step); metrics -> {out}")


if __name__ == "__main__":
    main()
