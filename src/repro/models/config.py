"""Composable architecture configuration.

One dataclass covers the ten assigned architectures (dense / MoE / SSM /
hybrid / enc-dec / VLM).  The same config drives:
  * the pure-JAX model definitions (``repro.models``),
  * the ELK operator-graph extraction (``repro.core.graph``),
  * the sharding rules and dry-run input specs (``repro.launch``).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int            # kv heads (GQA); == num_heads for MHA
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False        # RMS-norm on per-head q and k (qwen3)
    gated_mlp: bool = True       # SwiGLU/GeGLU two-matrix gate
    mlp_act: Literal["silu", "gelu", "relu"] = "silu"
    tie_embeddings: bool = False
    scale_embed: bool = False    # multiply embeddings by sqrt(d_model) (gemma)
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 -> full attention; >0 -> SWA width
    # which layers use SWA: "all", "none", or every-k pattern like hymba
    swa_layers: Literal["all", "none"] = "none"

    # --- MoE -----------------------------------------------------------
    moe_experts: int = 0         # 0 -> dense FFN
    moe_top_k: int = 1
    moe_d_ff: int = 0            # per-expert hidden (0 -> d_ff)
    moe_shared_d_ff: int = 0     # shared-expert hidden (0 -> no shared expert)
    moe_every: int = 1           # MoE on layers i % moe_every == moe_offset
    moe_offset: int = 0
    moe_first_dense: int = 0     # first k layers dense (kimi/deepseek style)
    moe_capacity_factor: float = 1.25

    # --- SSM / RWKV / hybrid --------------------------------------------
    ssm_state: int = 0           # mamba-style state size per channel
    rwkv: bool = False           # RWKV6 wkv recurrence instead of attention
    hybrid_parallel_ssm: bool = False  # hymba: attn heads ∥ mamba heads

    # --- encoder-decoder / frontends -------------------------------------
    encoder_layers: int = 0      # >0 -> enc-dec (whisper)
    encoder_seq: int = 0         # fixed encoder length (whisper: 1500 frames)
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    vision_patches: int = 0      # VLM: patch-embedding count prepended to text

    # --- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- lowering knobs (not architecture) --------------------------------
    # Python-loop instead of lax.scan for layer blocks / attention chunks:
    # used by the dry-run accounting variants (XLA cost_analysis counts a
    # while body once, not x trip count) and by reduced-L extrapolation.
    unroll_scan: bool = False
    # q-chunk size for the memory-bounded attention path (0 = single shot)
    attn_chunk: int = 512

    # ---- derived ----------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.rwkv

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM, hybrid, or sliding-window attention."""
        return self.rwkv or self.hybrid_parallel_ssm or (
            self.sliding_window > 0 and self.swa_layers == "all")

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe_experts:
            return False
        if i < self.moe_first_dense:
            return False
        return (i - self.moe_offset) % self.moe_every == 0

    def moe_hidden(self) -> int:
        return self.moe_d_ff or self.d_ff

    # -- parameter counts (exact, used for roofline MODEL_FLOPS) -----------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = v * d                                   # embed
        if not self.tie_embeddings:
            total += v * d                              # lm head
        enc = self.encoder_layers
        for i in range(self.num_layers):
            total += 2 * d                              # ln weights
            if self.rwkv:
                # time-mix: r,k,v,g,o (d x d) + decay/bonus + lora-ish mixers
                total += 5 * d * d + 4 * d
                total += 2 * d * ff                     # channel mix (k, v)
                continue
            if self.num_heads:
                total += d * (nq * hd) + (nq * hd) * d  # q, o
                total += 2 * d * (nkv * hd)             # k, v
                if self.qkv_bias:
                    total += (nq + 2 * nkv) * hd
            if self.hybrid_parallel_ssm:
                # mamba branch: in-proj (x,z), dt/B/C proj, out-proj
                total += 2 * d * d + d * (2 * self.ssm_state + d // 16) + d * d
            if self.is_moe_layer(i):
                e = self.moe_experts if not active_only else self.moe_top_k
                mff = self.moe_hidden()
                nmat = 3 if self.gated_mlp else 2
                total += e * nmat * d * mff
                total += d * self.moe_experts           # router (always dense)
                if self.moe_shared_d_ff:
                    total += nmat * d * self.moe_shared_d_ff
            else:
                nmat = 3 if self.gated_mlp else 2
                total += nmat * d * ff
        for _ in range(enc):
            total += 2 * d
            total += 4 * d * d                          # self-attn q,k,v,o
            total += 2 * d * ff                         # (whisper mlp non-gated)
        if enc:  # decoder cross-attention
            total += self.num_layers * 4 * d * d
        return int(total)

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)
