"""Modality frontend stubs (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; the frontend supplies precomputed
frame/patch embeddings).

These helpers produce deterministic synthetic embeddings with the right
shapes — the real conv/ViT towers are out of assignment scope and replaced
by ``input_specs()`` stand-ins in the dry-run."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def vision_patch_embeddings(cfg: ModelConfig, batch: int,
                            seed: int = 0) -> jax.Array:
    """InternViT stub: (B, patches, d_model) precomputed patch embeddings."""
    rng = jax.random.PRNGKey(seed)
    return jax.random.normal(
        rng, (batch, cfg.vision_patches, cfg.d_model), jnp.bfloat16) * 0.02


def audio_frame_embeddings(cfg: ModelConfig, batch: int,
                           seed: int = 0) -> jax.Array:
    """Whisper conv-frontend stub: (B, encoder_seq, d_model) mel-frame
    embeddings (the two stride-2 convs collapse 3000 mel frames to 1500)."""
    rng = jax.random.PRNGKey(seed)
    return jax.random.normal(
        rng, (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16) * 0.02


def frontend_embeddings(cfg: ModelConfig, batch: int, seed: int = 0):
    if cfg.frontend == "vision_stub":
        return {"embeds": vision_patch_embeddings(cfg, batch, seed)}
    if cfg.frontend == "audio_stub":
        return {"enc_embeds": audio_frame_embeddings(cfg, batch, seed)}
    return {}
