"""Model-layer primitives shared by every architecture in the zoo.

All functions are pure (params-in, activations-out), bf16-activation /
fp32-accumulation, and written so XLA GSPMD can shard them along the
(data, model) mesh axes declared in ``repro.distributed.sharding``:

* weights are einsum'd on their natural contraction axes (no reshapes that
  would break sharding propagation through the model axis),
* attention keeps a ``(batch, heads, seq, head_dim)`` layout with heads as
  the model-sharded axis,
* normalizations and softmax accumulate in fp32.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables for integer ``positions`` (any leading shape)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, H, S, D); sin/cos: (B, S, D/2) or (S, D/2)."""
    if sin.ndim == 2:
        sin = sin[None, None, :, :]
        cos = cos[None, None, :, :]
    else:
        sin = sin[:, None, :, :]
        cos = cos[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static attention behaviour derived from a ModelConfig."""
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    sliding_window: int = 0      # 0 = full
    qk_norm: bool = False
    softmax_scale: Optional[float] = None

    @property
    def scale(self) -> float:
        return self.softmax_scale or self.head_dim ** -0.5


def attn_mask_bias(spec: AttnSpec, q_pos: jax.Array, k_pos: jax.Array,
                   ) -> jax.Array:
    """Additive fp32 bias (Q, K): 0 where attendable, -inf where masked.

    q_pos/k_pos are absolute token positions, so the same code serves
    prefill (q_pos == k_pos grid) and decode (single q position against a
    cache whose live region is position-tagged)."""
    dq, dk = q_pos[:, None], k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if spec.causal:
        ok &= dk <= dq
    if spec.sliding_window:
        ok &= dk > dq - spec.sliding_window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  bias: Optional[jax.Array], spec: AttnSpec) -> jax.Array:
    """Reference GQA attention.

    q: (B, Hq, Sq, D);  k/v: (B, Hkv, Sk, D);  bias: (Sq, Sk) or None.
    Grouped heads are folded by reshaping q to (B, Hkv, G, Sq, D) so the
    kv tensors are never materialized per-q-head (GQA's entire point)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= spec.scale
    if bias is not None:
        logits = logits + bias
    # rows that are fully masked (e.g. cache slots beyond the window) must
    # not produce NaNs: max-subtract with a -inf guard.
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v)
    return out.reshape(b, hq, sq, d)


def chunked_gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          spec: AttnSpec, q_pos: jax.Array,
                          k_pos: jax.Array, *, chunk: int = 512,
                          unroll: bool = False, mesh=None) -> jax.Array:
    """Memory-bounded attention: q is processed in chunks so the live score
    tile is (..., chunk, Sk) instead of (..., Sq, Sk) — the XLA-lowering
    stand-in for the Pallas flash kernel (which replaces it on real TPU).

    Each chunk's q-seq dim is constrained onto the ``model`` mesh axis
    (sequence-parallel attention): head counts like 40 or kv=8 never
    divide a 16-way axis, a seq split always does.

    ``unroll=True`` uses a Python loop (dry-run accounting variants: XLA's
    cost model counts a scan body once; an unrolled loop is counted fully).
    """
    b, hq, sq, d = q.shape

    def constrain_seq(t):
        if mesh is None or "model" not in getattr(mesh, "shape", {}):
            return t
        m = mesh.shape["model"]
        if t.shape[2] % m:
            return t
        from jax.sharding import NamedSharding, PartitionSpec
        bp = tuple(ax for ax in ("pod", "data") if ax in mesh.shape)
        dp = 1
        for ax in bp:
            dp *= mesh.shape[ax]
        b_ax = bp if t.shape[0] % max(dp, 1) == 0 else None
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(mesh, PartitionSpec(b_ax, None, "model", None)))

    if chunk <= 0 or sq <= chunk:
        bias = attn_mask_bias(spec, q_pos, k_pos)
        return gqa_attention(constrain_seq(q), k, v, bias, spec)
    n = -(-sq // chunk)
    pad = n * chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=2 ** 30)
    qc = q.reshape(b, hq, n, chunk, d)
    pc = q_pos.reshape(n, chunk)

    def one(qi, pi):
        bias = attn_mask_bias(spec, pi, k_pos)
        return gqa_attention(constrain_seq(qi), k, v, bias, spec)

    if unroll:
        outs = [one(qc[:, :, i], pc[i]) for i in range(n)]
        out = jnp.stack(outs, axis=2)
    else:
        def body(_, xs):
            qi, pi = xs
            return None, one(qi, pi)
        _, out = jax.lax.scan(
            body, None, (jnp.moveaxis(qc, 2, 0), pc))
        out = jnp.moveaxis(out, 0, 2)
    out = out.reshape(b, hq, n * chunk, d)
    return out[:, :, :sq, :]


def qk_head_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS norm on q/k (qwen3). x: (B, H, S, D), scale: (D,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Projections & MLP
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None
           ) -> jax.Array:
    out = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def mlp(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """MLP block on the fused runtime path (kernels/fused_mlp).

    On TPU (or under ``force_kernels``) the whole up-proj -> activation ->
    down-proj chain runs as one Pallas grid with the intermediate staged
    in VMEM — the runtime twin of the FusedOp the fusion pass hands the
    scheduler.  The CPU fallback is the exact einsum composition this
    function used before fusion existed, so outputs are unchanged."""
    from repro.kernels.fused_mlp.ops import fused_mlp
    if cfg.gated_mlp:
        return fused_mlp(x, p["w_up"], p["w_down"], w_gate=p["w_gate"],
                         act=cfg.mlp_act)
    return fused_mlp(x, p["w_up"], p["w_down"], b_up=p.get("b_up"),
                     b_down=p.get("b_down"), act=cfg.mlp_act)


def mlp_params(rng, cfg: ModelConfig, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d ** -0.5
    s_ff = ff ** -0.5
    if cfg.gated_mlp:
        return {
            "w_gate": jax.random.normal(k1, (d, ff), dtype) * s_in,
            "w_up": jax.random.normal(k2, (d, ff), dtype) * s_in,
            "w_down": jax.random.normal(k3, (ff, d), dtype) * s_ff,
        }
    p = {
        "w_up": jax.random.normal(k1, (d, ff), dtype) * s_in,
        "w_down": jax.random.normal(k2, (ff, d), dtype) * s_ff,
    }
    if cfg.qkv_bias:   # opt-style fc biases travel with qkv_bias configs
        p["b_up"] = jnp.zeros((ff,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None,
                 z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy in fp32; optional z-loss regularizer."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
