"""Mixture-of-experts FFN (llama4-maverick, kimi-k2).

GShard-style capacity-factor einsum dispatch: shardable under GSPMD with the
expert dimension on the ``model``/``expert`` mesh axis, no ragged ops, and a
fixed compute shape (required for the multi-pod dry-run).  Tokens over
capacity are dropped (their combine weight is zero) — standard
capacity-factor semantics.

The ELK connection (paper §7 "Apply ELK to MoE"): expert weights are
late-bound preloads — the scheduler models the expert fetch as a preload op
whose earliest issue time is the router op (``Op.preload_dep`` in
``core/graph.py``).  At runtime the EP all_to_all below is the
"data-distribution phase" of the expert tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _act, linear


def capacity(tokens: int, experts: int, top_k: int, factor: float) -> int:
    cap = int(tokens * top_k * factor / experts) + 1
    # never below top_k (tiny smoke shapes must route) and never beyond
    # tokens*top_k (the dropless bound — more slots can't be used)
    return min(max(cap, top_k), tokens * top_k)


def router_weights(logits: jax.Array, top_k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Top-k routing with softmax-renormalized gates.

    logits: (T, E) -> gates (T, k) fp32, idx (T, k) int32."""
    lf = logits.astype(jnp.float32)
    gates, idx = jax.lax.top_k(lf, top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            capacity_factor: float | None = None,
            dropless: bool = False, mesh=None) -> jax.Array:
    """x: (T, d) token-major.  p: router (d,E), w_gate/w_up (E,d,ff),
    w_down (E,ff,d).  ``dropless`` sizes capacity so no assignment is ever
    dropped (decode uses this — T is just the batch there).

    Dispatch is scatter/gather (sort-free ranking + ``.at[].set`` with
    OOB-drop), not the GShard (T,E,C) einsum: at kimi-k2 scale the one-hot
    dispatch tensor is O(T*E*C) ~= tens of TB, while the scatter path is
    O(E*C*d + T*k*d)."""
    t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cf = capacity_factor or cfg.moe_capacity_factor
    c = t * k if dropless else capacity(t, e, k, cf)

    logits = linear(x, p["router"])                          # (T, E)
    gates, idx = router_weights(logits, k)                   # (T,k)

    # slot of each (token, slot-k) assignment inside its expert's buffer:
    # rank among all assignments to the same expert, in token order.
    flat_e = idx.reshape(t * k)                              # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (T*k,E)
    slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*k,)

    tok = jnp.arange(t * k) // k
    xe = jnp.zeros((e, c, d), x.dtype)
    # over-capacity slots (>= c) drop via scatter OOB semantics
    xe = xe.at[flat_e, slot].set(x[tok], mode="drop")        # (E,C,d)

    def constrain_ep(a):
        """Expert-parallel placement: E over the model axis (the expert
        dispatch is the paper's §7 data-distribution phase)."""
        if mesh is None or "model" not in getattr(mesh, "shape", {}):
            return a
        if a.shape[0] % mesh.shape["model"]:
            return a
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, PartitionSpec(
                "model", *([None] * (a.ndim - 1)))))

    xe = constrain_ep(xe)
    act = _act(cfg.mlp_act)
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        h = act(g) * u
    else:
        h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    ye = constrain_ep(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))  # (E,C,d)

    rows = ye.at[flat_e, slot].get(mode="fill", fill_value=0)  # (T*k,d)
    out = jnp.einsum("tk,tkd->td", gates.astype(jnp.float32),
                     rows.reshape(t, k, d).astype(jnp.float32))
    return out.astype(x.dtype)


def moe_params(rng, cfg: ModelConfig, dtype) -> dict:
    d, e = cfg.d_model, cfg.moe_experts
    ff = cfg.moe_hidden()
    ks = jax.random.split(rng, 4)
    s_in, s_ff = d ** -0.5, ff ** -0.5
    p = {"router": jax.random.normal(ks[0], (d, e), dtype) * s_in}
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(ks[1], (e, d, ff), dtype) * s_in
    p["w_up"] = jax.random.normal(ks[2], (e, d, ff), dtype) * s_in
    p["w_down"] = jax.random.normal(ks[3], (e, ff, d), dtype) * s_ff
    return p


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array,
                          num_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean_prob . mean_assign . E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T,E)
    assign = jax.nn.one_hot(idx[..., 0], num_experts, dtype=jnp.float32)
    return num_experts * jnp.mean(probs.mean(0) * assign.mean(0))
