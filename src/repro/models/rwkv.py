"""RWKV6 "Finch" time-mix and channel-mix (rwkv6-7b).

Data-dependent decay WKV recurrence (arXiv:2404.05892), per head with state
S in R^{D x D}:

    y_t = r_t @ (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T          w_t = exp(-exp(ww_t))

``ww_t`` is data-dependent (the "dynamic recurrence" of RWKV6; the low-rank
token-shift mixers of the full release are folded into the projections — the
op/FLOP structure the ELK graph models is unchanged).  Sequence mode runs a
``lax.scan`` over time; decode mode is the single-step recurrence with the
state carried in the serving cache (O(1) per token — why this arch owns the
``long_500k`` cell).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import linear, rms_norm


def wkv_step(state: jax.Array, r, k, v, w, u):
    """One recurrence step.  state: (B,H,D,D); r,k,v,w: (B,H,D); u: (H,D)."""
    kv = k[..., :, None] * v[..., None, :]              # (B,H,D,D)
    y = jnp.einsum("bhd,bhde->bhe", r, state + u[..., :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return new_state, y


def wkv_sequence(r, k, v, w, u, state):
    """r,k,v,w: (B,H,S,D) fp32; u: (H,D); state: (B,H,D,D).
    Returns (y (B,H,S,D), final_state)."""
    def step(s, xs):
        rt, kt, vt, wt = xs
        s, y = wkv_step(s, rt, kt, vt, wt, u)
        return s, y
    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))   # (S,B,H,D)
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2), state


def time_mix(x: jax.Array, p: dict, cfg: ModelConfig,
             state: jax.Array | None = None):
    """x: (B, S, d).  Returns (out (B,S,d), new_state (B,H,D,D))."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h

    def heads(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # (B,H,S,D)

    r = heads(linear(x, p["w_r"])).astype(jnp.float32)
    k = heads(linear(x, p["w_k"])).astype(jnp.float32)
    v = heads(linear(x, p["w_v"])).astype(jnp.float32)
    g = linear(x, p["w_g"])
    # data-dependent decay: per-channel base decay + token-conditioned delta
    ww = p["decay"].astype(jnp.float32).reshape(h, hd)[None, :, None, :] \
        + heads(linear(x, p["w_decay"])).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww))
    u = p["bonus"].astype(jnp.float32).reshape(h, hd)

    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    y, new_state = wkv_sequence(r, k, v, w, u, state)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    # group-norm per head approximated by rms over channels (ln_x in rwkv)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = linear(y * jax.nn.silu(g), p["w_o"])
    return out, new_state


def channel_mix(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    k = jax.nn.relu(linear(x, p["w_ck"])) ** 2
    return linear(k, p["w_cv"])


def rwkv_layer_params(rng, cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 8)
    s = d ** -0.5
    return {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "ln_x": jnp.zeros((d,), dtype),
        "w_r": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d, d), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d, d), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d, d), dtype) * s,
        "w_decay": jax.random.normal(ks[5], (d, d), dtype) * s * 0.1,
        "decay": jnp.full((d,), 0.5, dtype),
        "bonus": jnp.zeros((d,), dtype),
        "w_ck": jax.random.normal(ks[6], (d, ff), dtype) * s,
        "w_cv": jax.random.normal(ks[7], (ff, d), dtype) * ff ** -0.5,
    }


def rwkv_block(x: jax.Array, p: dict, cfg: ModelConfig,
               state: jax.Array | None = None):
    h, new_state = time_mix(rms_norm(x, p["ln1"], cfg.norm_eps), p, cfg, state)
    x = x + h
    x = x + channel_mix(rms_norm(x, p["ln2"], cfg.norm_eps), p, cfg)
    return x, new_state
