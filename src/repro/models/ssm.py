"""Mamba-style selective-scan branch (hymba-1.5b's parallel SSM heads).

Simplified but real selective SSM: per channel a state vector of size N with
data-dependent (dt, B, C):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

A is a learned negative-real diagonal (d, N).  Sequence mode scans over time;
decode carries ``h`` in the serving cache (hymba's O(1)-state half — with the
SWA attention half this is what makes the arch ``long_500k``-eligible).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import linear


def ssm_scan(x, dt, b_t, c_t, a, d_skip, state):
    """x: (B,S,d) fp32; dt: (B,S,d); b_t/c_t: (B,S,N); a: (d,N);
    state: (B,d,N).  Returns (y (B,S,d), final_state)."""
    da = jnp.exp(dt[..., None] * a)                      # (B,S,d,N)
    dbx = dt[..., None] * b_t[:, :, None, :] * x[..., None]

    def step(h, xs):
        da_t, dbx_t, c = xs                              # (B,d,N),(B,d,N),(B,N)
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0),
          jnp.moveaxis(c_t, 1, 0))
    state, ys = lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1) + d_skip * x              # (B,S,d)
    return y, state


def ssm_branch(x: jax.Array, p: dict, cfg: ModelConfig,
               state: jax.Array | None = None):
    """x: (B,S,d_model) -> (out, new_state (B,d,N))."""
    b, s, d = x.shape
    n = cfg.ssm_state
    xz = linear(x, p["w_in"])                            # (B,S,2d)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = xi.astype(jnp.float32)
    dt = jax.nn.softplus(linear(xi, p["w_dt"].astype(jnp.float32))
                         + p["dt_bias"].astype(jnp.float32))
    bc = linear(xi, p["w_bc"].astype(jnp.float32))       # (B,S,2N)
    b_t, c_t = jnp.split(bc, 2, axis=-1)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))         # (d,N) negative-real
    if state is None:
        state = jnp.zeros((b, d, n), jnp.float32)
    y, new_state = ssm_scan(xi, dt, b_t, c_t, a, p["d_skip"].astype(jnp.float32),
                            state)
    out = linear(y.astype(x.dtype) * jax.nn.silu(z), p["w_out"])
    return out, new_state


def ssm_params(rng, cfg: ModelConfig, dtype) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    return {
        "w_in": jax.random.normal(ks[0], (d, 2 * d), dtype) * s,
        "w_dt": jax.random.normal(ks[1], (d, d), dtype) * s * 0.1,
        "dt_bias": jnp.full((d,), -4.0, dtype),   # softplus ~= 0.018
        "w_bc": jax.random.normal(ks[2], (d, 2 * n), dtype) * s,
        "a_log": jnp.zeros((d, n), dtype),        # A = -1
        "d_skip": jnp.ones((d,), dtype),
        "w_out": jax.random.normal(ks[3], (d, d), dtype) * s,
    }
