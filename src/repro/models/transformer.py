"""Unified model definition for every assigned architecture.

One functional model covers dense / GQA / SWA / qk-norm / GeGLU / MoE /
RWKV6 / hybrid-SSM / enc-dec / VLM-prefix families, driven entirely by
``ModelConfig``.  Three entry points:

* ``forward_train``  — full-sequence causal forward, returns logits.
* ``prefill``        — forward that also fills a serving cache.
* ``decode_step``    — one token against the cache (``serve_step`` shapes).

Compile-time scalability (the multi-pod dry-run lowers 61-layer trillion-
parameter configs for 512 devices): decoder layers run under ``lax.scan``
over *layer blocks* with stacked parameters.  A block is ``period`` layers,
where ``period`` is the MoE interleave (llama4: dense/MoE alternation => 2)
— so the scanned body is structurally identical across blocks and the HLO
stays O(period), not O(num_layers).  Leading non-periodic layers
(kimi's dense first layer) run unrolled.

Caches are stacked over layers (leading dim ``num_layers``) and threaded
through the scan as xs/ys slices.  KV caches are ring buffers of capacity
``C``: exact attention while ``pos < C`` and sliding-window semantics
beyond — full-attention serving sizes ``C = seq_len``, SWA archs size
``C = window`` (how hymba/danube hold ``long_500k`` state in O(window)).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (AttnSpec, apply_rope, attn_mask_bias,
                                 chunked_gqa_attention, gqa_attention,
                                 linear, mlp, mlp_params, qk_head_norm,
                                 rms_norm, rope_tables, softmax_xent)

PyTree = Any
_POS_SENTINEL = jnp.int32(2 ** 30)   # cache slots not yet written


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.rwkv:
        return ["rwkv"] * cfg.num_layers
    return ["moe" if cfg.is_moe_layer(i) else "dense"
            for i in range(cfg.num_layers)]


def block_structure(cfg: ModelConfig) -> tuple[int, int, int]:
    """(prefix_len, period, n_blocks): prefix layers run unrolled, then
    n_blocks scan iterations of `period` layers each."""
    kinds = layer_kinds(cfg)
    prefix = cfg.moe_first_dense if cfg.moe_experts else 0
    body = kinds[prefix:]
    period = max(cfg.moe_every, 1) if cfg.moe_experts else 1
    if len(body) % period:
        # ragged tail: fold it into the prefix from the far end is wrong —
        # instead shrink the scanned part and unroll the tail as prefix2.
        # Keep it simple: grow prefix until divisible.
        extra = len(body) % period
        prefix += extra
        body = kinds[prefix:]
    assert all(body[i] == body[i % period] for i in range(len(body)))
    return prefix, period, len(body) // period


def attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        causal=True,
        sliding_window=(cfg.sliding_window
                        if cfg.swa_layers == "all" else 0),
        qk_norm=cfg.qk_norm,
    )


# ---------------------------------------------------------------------------
# per-layer parameters
# ---------------------------------------------------------------------------

def _attn_params(rng, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "w_q": jax.random.normal(ks[0], (d, nq * hd), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d, nkv * hd), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d, nkv * hd), dtype) * s,
        "w_o": jax.random.normal(ks[3], (nq * hd, d), dtype) * (nq * hd) ** -0.5,
    }
    if cfg.qkv_bias and not cross:
        p["b_q"] = jnp.zeros((nq * hd,), dtype)
        p["b_k"] = jnp.zeros((nkv * hd,), dtype)
        p["b_v"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def decoder_layer_params(rng, cfg: ModelConfig, kind: str, dtype) -> dict:
    if kind == "rwkv":
        return rwkv_mod.rwkv_layer_params(rng, cfg, dtype)
    ks = jax.random.split(rng, 5)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
         "attn": _attn_params(ks[0], cfg, dtype)}
    if kind == "moe":
        p["moe"] = moe_mod.moe_params(ks[1], cfg, dtype)
        if cfg.moe_shared_d_ff:
            p["shared_mlp"] = mlp_params(ks[2], cfg, d,
                                         cfg.moe_shared_d_ff, dtype)
    else:
        p["mlp"] = mlp_params(ks[1], cfg, d, cfg.d_ff, dtype)
    if cfg.hybrid_parallel_ssm:
        p["ssm"] = ssm_mod.ssm_params(ks[3], cfg, dtype)
    if cfg.encoder_layers:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xattn"] = _attn_params(ks[4], cfg, dtype, cross=True)
    return p


def encoder_layer_params(rng, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(rng, 2)
    d = cfg.d_model
    return {"ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "attn": _attn_params(ks[0], cfg, dtype),
            "mlp": mlp_params(ks[1], cfg, d, cfg.d_ff, dtype)}


def init_params(rng, cfg: ModelConfig,
                decode_positions: int = 0) -> PyTree:
    """Full parameter pytree.  ``decode_positions`` sizes whisper's learned
    decoder position table (0 -> 4096)."""
    dtype = jnp.dtype(cfg.param_dtype)
    kE, kH, kenc, kpre, kblk, kpos = jax.random.split(rng, 6)
    d, v = cfg.d_model, cfg.vocab_size
    prefix, period, n_blocks = block_structure(cfg)
    kinds = layer_kinds(cfg)

    params: dict = {
        "embed": jax.random.normal(kE, (v, d), dtype) * d ** -0.5,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kH, (d, v), dtype) * d ** -0.5

    if cfg.encoder_layers:
        enc_keys = jax.random.split(kenc, cfg.encoder_layers)
        params["encoder"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[encoder_layer_params(k, cfg, dtype) for k in enc_keys])
        params["enc_norm"] = jnp.zeros((d,), dtype)
        params["enc_pos"] = (jax.random.normal(
            kpos, (max(cfg.encoder_seq, 1), d), dtype) * 0.02)
        npos = decode_positions or 4096
        params["dec_pos"] = jax.random.normal(kpos, (npos, d), dtype) * 0.02

    pre_keys = jax.random.split(kpre, max(prefix, 1))
    params["prefix"] = [decoder_layer_params(pre_keys[i], cfg, kinds[i], dtype)
                        for i in range(prefix)]

    blocks = []
    blk_keys = jax.random.split(kblk, max(n_blocks * period, 1))
    for slot in range(period):
        kind = kinds[prefix + slot]
        per_block = [decoder_layer_params(blk_keys[b * period + slot], cfg,
                                          kind, dtype)
                     for b in range(n_blocks)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block))
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# serving cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheSpec:
    capacity: int                  # KV slots per layer (ring buffer)
    batch: int
    kv_dtype: Any = jnp.bfloat16   # bf16 | int8 (quantized serving cache)
    per_slot: bool = False         # independent per-request slots
                                   # (pos: (B,), slot_pos: (B, C))


def init_cache(cfg: ModelConfig, spec: CacheSpec) -> dict:
    L, B, C = cfg.num_layers, spec.batch, spec.capacity
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    if spec.per_slot and cfg.encoder_layers:
        raise ValueError("per-slot caches do not support enc-dec models")
    cache: dict = {"pos": (jnp.zeros((B,), jnp.int32) if spec.per_slot
                           else jnp.zeros((), jnp.int32))}
    if not cfg.rwkv:
        kv_shape = (L, B, nkv, C, hd)
        cache["k"] = jnp.zeros(kv_shape, spec.kv_dtype)
        cache["v"] = jnp.zeros(kv_shape, spec.kv_dtype)
        if spec.kv_dtype == jnp.int8:
            cache["k_scale"] = jnp.zeros((L, B, nkv, C, 1), jnp.bfloat16)
            cache["v_scale"] = jnp.zeros((L, B, nkv, C, 1), jnp.bfloat16)
        cache["slot_pos"] = jnp.full((B, C) if spec.per_slot else (C,),
                                     _POS_SENTINEL, jnp.int32)
    if cfg.rwkv:
        h = cfg.num_heads
        cache["rwkv_state"] = jnp.zeros((L, B, h, cfg.d_model // h,
                                         cfg.d_model // h), jnp.float32)
    if cfg.hybrid_parallel_ssm:
        cache["ssm_state"] = jnp.zeros((L, B, cfg.d_model, cfg.ssm_state),
                                       jnp.float32)
    if cfg.encoder_layers:
        E = cfg.encoder_seq
        cache["cross_k"] = jnp.zeros((L, B, nkv, E, hd), jnp.bfloat16)
        cache["cross_v"] = jnp.zeros((L, B, nkv, E, hd), jnp.bfloat16)
    return cache


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)
            ).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _project_qkv(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads

    def heads(t, n):
        return t.reshape(b, s, n, hd).transpose(0, 2, 1, 3)

    q = heads(linear(x, p["w_q"], p.get("b_q")), nq)
    k = heads(linear(x, p["w_k"], p.get("b_k")), nkv)
    v = heads(linear(x, p["w_v"], p.get("b_v")), nkv)
    if cfg.qk_norm:
        q = qk_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = qk_head_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _self_attention_full(x, p, cfg: ModelConfig, spec: AttnSpec,
                         sin, cos, positions, mesh=None, layout="tp"):
    """Training/prefill attention over the whole sequence.

    Sharding: attention internals are *sequence-parallel* over the model
    axis (q's seq dim sharded, K/V replicated within the group) — head
    counts like 40 or kv=8 don't divide a 16-way model axis, and a seq
    split keeps the score tile exactly N-way sharded for every arch.  The
    q-chunked path bounds the live score tile (Pallas flash kernel
    replaces it on real TPU)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    if not cfg.rwkv and cfg.num_heads:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    seq_tp = mesh is not None and "model" in getattr(mesh, "shape", {}) \
        and layout == "tp"
    if seq_tp:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import batch_axes
        bp = batch_axes(mesh)
        bq = bp if b % max(
            int(np.prod([mesh.shape[a] for a in bp])), 1) == 0 else None
        # K/V batch-sharded, replicated over model (seq-parallel q does the
        # model-axis sharding; partial head shardings would reshard every
        # layer for head counts like 40 or kv=8 on a 16-way axis).  Under
        # the fsdp2d layout activations are replicated over model (weights
        # gather instead) and these constraints would only churn reshards.
        k = jax.lax.with_sharding_constraint(
            k, NamedSharding(mesh, P(bq, None, None, None)))
        v = jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P(bq, None, None, None)))
    out = chunked_gqa_attention(q, k, v, spec, positions, positions,
                                chunk=cfg.attn_chunk,
                                unroll=cfg.unroll_scan,
                                mesh=mesh if seq_tp else None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return linear(out, p["w_o"]), k, v


def _self_attention_decode(x, p, cfg: ModelConfig, spec: AttnSpec,
                           k_cache, v_cache, kq_scales, slot_pos, pos):
    """x: (B,1,d) one token at absolute position ``pos`` against the ring
    cache (B,Hkv,C,hd).  Returns (out, new_k_slice, new_v_slice)."""
    b, s, d = x.shape
    c = k_cache.shape[2]
    q, k_new, v_new = _project_qkv(x, p, cfg)         # (B,H,1,hd)
    sin, cos = rope_tables(jnp.full((1,), pos), spec.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)
    slot = pos % c
    if kq_scales is not None:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k_cache = lax.dynamic_update_slice_in_dim(k_cache, kq, slot, axis=2)
        v_cache = lax.dynamic_update_slice_in_dim(v_cache, vq, slot, axis=2)
        k_sc = lax.dynamic_update_slice_in_dim(kq_scales[0], ks, slot, axis=2)
        v_sc = lax.dynamic_update_slice_in_dim(kq_scales[1], vs, slot, axis=2)
        k = _dequantize_kv(k_cache, k_sc)
        v = _dequantize_kv(v_cache, v_sc)
        new_scales = (k_sc, v_sc)
    else:
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), slot, axis=2)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), slot, axis=2)
        k, v = k_cache, v_cache
        new_scales = None
    bias = attn_mask_bias(spec, jnp.full((1,), pos), slot_pos)
    out = gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), bias, spec)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return linear(out, p["w_o"]), k_cache, v_cache, new_scales


def _self_attention_slots(x, p, cfg: ModelConfig, spec: AttnSpec,
                          k_cache, v_cache, kq_scales, slot_pos, positions):
    """Per-slot cached attention: every batch row sits at its own absolute
    position.  x: (B,T,d), positions: (B,T) per-row token positions,
    slot_pos: (B,C) per-row ring tags (already updated for this step's
    writes), caches (B,Hkv,C,hd).  Serves both the continuous-batching
    decode step (T=1, B=slots) and chunked prefill (B=1, T=chunk)."""
    b, t, d = x.shape
    c = k_cache.shape[2]
    q, k_new, v_new = _project_qkv(x, p, cfg)          # (B,H,T,hd)
    sin, cos = rope_tables(positions, spec.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)

    # scatter the T new K/V rows into each row's ring slots: touches only
    # the T written slots (T <= C keeps them distinct), so with donated
    # buffers the per-token write is O(T), not O(C)
    rows = jnp.arange(b)[:, None]                      # (B,1)
    slots = positions % c                              # (B,T)

    def scatter(buf, val):                             # val: (B,H,T,*)
        return buf.at[rows, :, slots, :].set(
            val.astype(buf.dtype).transpose(0, 2, 1, 3),
            unique_indices=True)

    if kq_scales is not None:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k_cache = scatter(k_cache, kq)
        v_cache = scatter(v_cache, vq)
        k_sc = scatter(kq_scales[0], ks)
        v_sc = scatter(kq_scales[1], vs)
        k = _dequantize_kv(k_cache, k_sc)
        v = _dequantize_kv(v_cache, v_sc)
        new_scales = (k_sc, v_sc)
    else:
        k_cache = scatter(k_cache, k_new)
        v_cache = scatter(v_cache, v_new)
        k, v = k_cache, v_cache
        new_scales = None

    # per-row additive mask from the ring tags (sentinel slots mask out)
    ok = slot_pos[:, None, :] <= positions[:, :, None]
    if spec.sliding_window:
        ok &= slot_pos[:, None, :] > positions[:, :, None] - spec.sliding_window
    bias = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
    bias = bias[:, None, None, :, :]                   # (B,1,1,T,C)
    out = gqa_attention(q, k.astype(q.dtype), v.astype(q.dtype), bias, spec)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
    return linear(out, p["w_o"]), k_cache, v_cache, new_scales


def _cross_attention(x, p, cfg: ModelConfig, ck, cv, mesh=None):
    """Cross-attention, q-chunked like self-attention: the unchunked
    (B,H,Sq,Senc) fp32 score tensor dominated whisper training memory
    (EXPERIMENTS.md §Perf iteration 6)."""
    b, s, d = x.shape
    hd, nq = cfg.resolved_head_dim, cfg.num_heads
    q = linear(x, p["w_q"]).reshape(b, s, nq, hd).transpose(0, 2, 1, 3)
    spec = AttnSpec(nq, cfg.num_kv_heads, hd, causal=False)
    senc = ck.shape[2]
    q_pos = jnp.zeros((s,), jnp.int32)       # non-causal: mask is all-open
    k_pos = jnp.zeros((senc,), jnp.int32)
    out = chunked_gqa_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                spec, q_pos, k_pos, chunk=cfg.attn_chunk,
                                unroll=cfg.unroll_scan, mesh=mesh)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return linear(out, p["w_o"])


def _ffn(x, p, cfg: ModelConfig, kind: str, mode: str = "train",
         mesh=None):
    if kind == "moe":
        b, s, d = x.shape
        out = moe_mod.moe_ffn(x.reshape(b * s, d), p["moe"], cfg,
                              dropless=(mode in ("decode", "slots")),
                              mesh=mesh)
        out = out.reshape(b, s, d)
        if cfg.moe_shared_d_ff:
            out = out + mlp(x, p["shared_mlp"], cfg)
        return out
    return mlp(x, p["mlp"], cfg)


def _decoder_layer(x, p, cfg: ModelConfig, kind: str, spec: AttnSpec,
                   ctx: dict, layer_cache: Optional[dict]):
    """Apply one decoder layer.  Returns (x, updated layer cache slices)."""
    new_cache: dict = {}
    if kind == "rwkv":
        state = layer_cache.get("rwkv_state") if layer_cache else None
        x, new_state = rwkv_mod.rwkv_block(x, p, cfg, state)
        if layer_cache is not None:
            new_cache["rwkv_state"] = new_state
        return x, new_cache

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if ctx["mode"] == "decode":
        attn_out, k_c, v_c, scales = _self_attention_decode(
            h, p["attn"], cfg, spec, layer_cache["k"], layer_cache["v"],
            layer_cache.get("scales"), ctx["slot_pos"], ctx["pos"])
        new_cache.update(k=k_c, v=v_c)
        if scales is not None:
            new_cache["scales"] = scales
    elif ctx["mode"] == "slots":
        attn_out, k_c, v_c, scales = _self_attention_slots(
            h, p["attn"], cfg, spec, layer_cache["k"], layer_cache["v"],
            layer_cache.get("scales"), ctx["slot_pos"], ctx["positions"])
        new_cache.update(k=k_c, v=v_c)
        if scales is not None:
            new_cache["scales"] = scales
    else:
        attn_out, k, v = _self_attention_full(
            h, p["attn"], cfg, spec, ctx["sin"], ctx["cos"],
            ctx["positions"], ctx.get("mesh"), ctx.get("layout", "tp"))
        if layer_cache is not None:   # prefill: write the cache
            c = layer_cache["k"].shape[2]
            s = k.shape[2]
            kw = k[:, :, -c:, :]
            vw = v[:, :, -c:, :]
            if layer_cache["k"].dtype == jnp.int8:
                kq, ks = _quantize_kv(kw)
                vq, vs = _quantize_kv(vw)
                new_cache.update(
                    k=_fill_ring(layer_cache["k"], kq, s),
                    v=_fill_ring(layer_cache["v"], vq, s),
                    scales=(_fill_ring(layer_cache["scales"][0], ks, s),
                            _fill_ring(layer_cache["scales"][1], vs, s)))
            else:
                new_cache.update(
                    k=_fill_ring(layer_cache["k"], kw, s),
                    v=_fill_ring(layer_cache["v"], vw, s))

    if cfg.hybrid_parallel_ssm:
        state = layer_cache.get("ssm_state") if layer_cache else None
        ssm_out, new_state = ssm_mod.ssm_branch(h, p["ssm"], cfg, state)
        attn_out = attn_out + ssm_out
        if layer_cache is not None:
            new_cache["ssm_state"] = new_state
    x = x + attn_out

    if cfg.encoder_layers:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + _cross_attention(hx, p["xattn"], cfg,
                                 ctx["cross_k"], ctx["cross_v"],
                                 ctx.get("mesh"))

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn(h2, p, cfg, kind, ctx["mode"], ctx.get("mesh"))
    return x, new_cache


def _fill_ring(buf: jax.Array, val: jax.Array, total_seq: int) -> jax.Array:
    """Write a prefill's last-C tokens into the ring with the true ring
    layout: position ``p`` lands at slot ``p % C`` (so later decode steps
    evict exactly the token leaving the window)."""
    c = buf.shape[2]
    s = val.shape[2]           # = min(total_seq, c)
    if s < c:
        val = jnp.pad(val, ((0, 0), (0, 0), (0, c - s), (0, 0)))
    shift = (total_seq - s) % c
    if shift:
        val = jnp.roll(val, shift, axis=2)
    return val.astype(buf.dtype)


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if embeds is not None and cfg.frontend == "vision_stub":
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _encode(params, cfg: ModelConfig, enc_embeds):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = enc_embeds + params["enc_pos"][None, :enc_embeds.shape[1], :]
    s = x.shape[1]
    spec = AttnSpec(cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
                    causal=False)
    positions = jnp.arange(s)
    sin, cos = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)

    zero_pos = jnp.zeros((s,), jnp.int32)   # non-causal: all-open mask

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h, p["attn"], cfg)
        out = chunked_gqa_attention(q, k, v, spec, zero_pos, zero_pos,
                                    chunk=cfg.attn_chunk,
                                    unroll=cfg.unroll_scan)
        b, hq, sq, hd = out.shape
        out = out.transpose(0, 2, 1, 3).reshape(b, sq, hq * hd)
        x = x + linear(out, p["attn"]["w_o"])
        x = x + mlp(rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg)
        return x, None

    if cfg.unroll_scan:
        for li in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[li], params["encoder"]))
    else:
        x, _ = lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _seq_shard_hidden(x, mesh):
    """Sequence-parallel residual stream: the (B,S,d) hidden state carried
    between blocks is sharded (batch->data, seq->model).  This is what the
    remat scan *saves* per block — unsharded it dominates training HBM."""
    if mesh is None or "model" not in getattr(mesh, "shape", {}) \
            or x.ndim != 3 or x.shape[1] % mesh.shape["model"]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    bp = tuple(ax for ax in ("pod", "data") if ax in mesh.shape)
    dp = 1
    for ax in bp:
        dp *= mesh.shape[ax]
    b_ax = bp if x.shape[0] % max(dp, 1) == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, "model", None)))


def _run_decoder(params, cfg: ModelConfig, x, ctx, cache, remat=False):
    """Apply prefix layers then the scanned blocks; threads per-layer cache
    slices in/out.  Returns (x, new_cache_layers: list aligned to layers)."""
    prefix, period, n_blocks = block_structure(cfg)
    kinds = layer_kinds(cfg)
    spec = attn_spec(cfg)
    new_layers: list[Optional[dict]] = [None] * cfg.num_layers

    def cache_slice(li):
        if cache is None:
            return None
        out = {}
        for key in ("k", "v", "rwkv_state", "ssm_state"):
            if key in cache:
                out[key] = cache[key][li]
        if "k_scale" in cache:
            out["scales"] = (cache["k_scale"][li], cache["v_scale"][li])
        return out

    for li in range(prefix):
        lc = cache_slice(li)
        x, nc = _decoder_layer(x, params["prefix"][li], cfg, kinds[li],
                               spec, ctx, lc)
        new_layers[li] = nc

    if n_blocks:
        xs_cache = None
        if cache is not None:
            def stack_blocks(arr):
                L = arr.shape[0]
                body = arr[prefix:prefix + n_blocks * period]
                return body.reshape((n_blocks, period) + arr.shape[1:])
            xs_cache = {}
            for key in ("k", "v", "rwkv_state", "ssm_state"):
                if key in cache:
                    xs_cache[key] = stack_blocks(cache[key])
            if "k_scale" in cache:
                xs_cache["scales"] = (stack_blocks(cache["k_scale"]),
                                      stack_blocks(cache["v_scale"]))

        def block_body(x, xs):
            bparams, bcache = xs
            outs = []
            for slot in range(period):
                kind = kinds[prefix + slot]
                lc = (jax.tree.map(lambda a: a[slot], bcache)
                      if bcache is not None else None)
                x, nc = _decoder_layer(x, bparams[slot], cfg, kind,
                                       spec, ctx, lc)
                outs.append(nc)
            ys = (jax.tree.map(lambda *zs: jnp.stack(zs), *outs)
                  if outs[0] else None)
            x = _seq_shard_hidden(x, ctx.get("mesh"))
            return x, ys

        if remat:
            block_body = jax.checkpoint(block_body)
        bparams = tuple(params["blocks"])
        if cfg.unroll_scan:
            # Python loop: accounting variants (cost_analysis counts every
            # unrolled body; a while/scan body is counted once)
            ys_list = []
            for bi in range(n_blocks):
                xs_i = jax.tree.map(lambda a: a[bi], (bparams, xs_cache))
                x, ys_i = block_body(x, xs_i)
                ys_list.append(ys_i)
            ys = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)
                  if ys_list and ys_list[0] is not None else None)
        else:
            x, ys = lax.scan(block_body, x, (bparams, xs_cache))
        if ys is not None:
            # unstack ys back into per-layer entries
            flat = jax.tree.map(
                lambda a: a.reshape((n_blocks * period,) + a.shape[2:]), ys)
            for off in range(n_blocks * period):
                new_layers[prefix + off] = jax.tree.map(
                    lambda a: a[off], flat)
    return x, new_layers


def _merge_cache(cfg: ModelConfig, cache: dict, new_layers, new_pos,
                 slot_pos=None) -> dict:
    out = dict(cache)
    if new_layers[0] is None and all(nl is None for nl in new_layers):
        out["pos"] = new_pos
        return out

    def gather(key, sub=None):
        vals = []
        for nl in new_layers:
            v = nl[key]
            if sub is not None:
                v = v[sub]
            vals.append(v)
        return jnp.stack(vals)

    any_layer = new_layers[0]
    if "k" in any_layer:
        out["k"] = gather("k")
        out["v"] = gather("v")
        if "scales" in any_layer:
            out["k_scale"] = gather("scales", 0)
            out["v_scale"] = gather("scales", 1)
    for key in ("rwkv_state", "ssm_state"):
        if key in any_layer:
            out[key] = gather(key)
    out["pos"] = new_pos
    if slot_pos is not None:
        out["slot_pos"] = slot_pos
    return out


def forward_train(params, cfg: ModelConfig, tokens: jax.Array,
                  embeds: Optional[jax.Array] = None,
                  enc_embeds: Optional[jax.Array] = None,
                  remat: bool = True, mesh=None,
                  layout: str = "tp") -> jax.Array:
    """Causal forward over (B, S) tokens -> (B, S[, +patches], V) logits."""
    x = _embed(params, cfg, tokens, embeds)
    s = x.shape[1]
    positions = jnp.arange(s)
    sin, cos = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    ctx = {"mode": "train", "sin": sin, "cos": cos, "positions": positions,
           "mesh": mesh, "layout": layout}
    if cfg.encoder_layers:
        ctx["enc_out"] = _encode(params, cfg, enc_embeds)
        x = x + params["dec_pos"][None, :s, :]
    x, _ = _run_decoder_with_cross(params, cfg, x, ctx, None, remat)
    return _logits(params, cfg, x)


def _run_decoder_with_cross(params, cfg, x, ctx, cache, remat=False):
    """Wrapper that materializes per-layer cross-attention K/V lazily.

    For enc-dec models the layer body projects enc_out with its own xattn
    weights, so ctx carries enc_out; _decoder_layer reads cross_k/cross_v —
    we monkey-patch them per layer via a ctx copy.  Cleanest without
    breaking the scan: precompute nothing, let the layer project."""
    if not cfg.encoder_layers:
        return _run_decoder(params, cfg, x, ctx, cache, remat)
    # enc-dec models are small (whisper-tiny): run layers unrolled with
    # per-layer cross K/V computed from enc_out or read from the cache.
    kinds = layer_kinds(cfg)
    spec = attn_spec(cfg)
    new_layers = [None] * cfg.num_layers
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    all_params = ([params["prefix"][i] for i in range(len(params["prefix"]))]
                  + _unstack_blocks(params, cfg))
    for li in range(cfg.num_layers):
        p = all_params[li]
        if "enc_out" in ctx and ctx["enc_out"] is not None:
            eo = ctx["enc_out"]
            b, es, d = eo.shape
            ck = linear(eo, p["xattn"]["w_k"]).reshape(
                b, es, nkv, hd).transpose(0, 2, 1, 3)
            cv = linear(eo, p["xattn"]["w_v"]).reshape(
                b, es, nkv, hd).transpose(0, 2, 1, 3)
        else:
            ck = cache["cross_k"][li]
            cv = cache["cross_v"][li]
        lctx = dict(ctx)
        lctx["cross_k"], lctx["cross_v"] = ck, cv
        lc = None
        if cache is not None:
            lc = {"k": cache["k"][li], "v": cache["v"][li]}
            if "k_scale" in cache:
                lc["scales"] = (cache["k_scale"][li], cache["v_scale"][li])
        if remat and ctx["mode"] == "train" and lc is None:
            # remat per layer; only array leaves may cross the checkpoint
            stat = {k: v for k, v in lctx.items() if not hasattr(v, "ndim")}
            arrs = {k: v for k, v in lctx.items() if hasattr(v, "ndim")}
            kind_i = kinds[li]

            def f(x, p, actx, _stat=stat, _kind=kind_i):
                return _decoder_layer(x, p, cfg, _kind, spec,
                                      {**_stat, **actx}, None)

            x, nc = jax.checkpoint(f)(x, p, arrs)
        else:
            x, nc = _decoder_layer(x, p, cfg, kinds[li], spec, lctx, lc)
        if cache is not None:
            nc["cross_k"], nc["cross_v"] = ck, cv
        new_layers[li] = nc
    return x, new_layers


def _unstack_blocks(params, cfg: ModelConfig) -> list:
    prefix, period, n_blocks = block_structure(cfg)
    out = []
    for b in range(n_blocks):
        for slot in range(period):
            out.append(jax.tree.map(lambda a: a[b], params["blocks"][slot]))
    return out


def prefill(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
            embeds=None, enc_embeds=None, mesh=None
            ) -> tuple[jax.Array, dict]:
    """Process the prompt, fill the cache, return last-token logits."""
    x = _embed(params, cfg, tokens, embeds)
    s = x.shape[1]
    positions = jnp.arange(s)
    sin, cos = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    ctx = {"mode": "prefill", "sin": sin, "cos": cos, "positions": positions,
           "mesh": mesh}
    if cfg.encoder_layers:
        ctx["enc_out"] = _encode(params, cfg, enc_embeds)
        x = x + params["dec_pos"][None, :s, :]
    x, new_layers = _run_decoder_with_cross(params, cfg, x, ctx, cache)
    slot_pos = None
    if "slot_pos" in cache:
        cap = cache["slot_pos"].shape[0]
        idx = jnp.arange(cap)
        if s <= cap:
            slot_pos = jnp.where(idx < s, idx, _POS_SENTINEL)
        else:       # ring layout: slot j holds position p=start+((j-start)%C)
            start = s - cap
            slot_pos = start + (idx - start) % cap
    new_cache = _merge_cache(cfg, cache, new_layers, jnp.int32(s), slot_pos)
    if cfg.encoder_layers and new_layers[0] is not None:
        new_cache["cross_k"] = jnp.stack([nl["cross_k"] for nl in new_layers])
        new_cache["cross_v"] = jnp.stack([nl["cross_v"] for nl in new_layers])
    return _logits(params, cfg, x[:, -1:, :]), new_cache


def decode_step(params, cfg: ModelConfig, token: jax.Array, cache: dict,
                mesh=None) -> tuple[jax.Array, dict]:
    """One serving step: token (B,) int32 -> (logits (B,1,V), new cache)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache["pos"]
    if cfg.encoder_layers:
        npos = params["dec_pos"].shape[0]
        x = x + params["dec_pos"][jnp.minimum(pos, npos - 1)][None, None, :]
    slot_pos = None
    if "slot_pos" in cache:   # tag the new token's slot *before* attention
        c = cache["slot_pos"].shape[0]
        slot_pos = cache["slot_pos"].at[pos % c].set(pos)
    ctx = {"mode": "decode", "pos": pos, "slot_pos": slot_pos,
           "enc_out": None, "mesh": mesh}
    x, new_layers = _run_decoder_with_cross(params, cfg, x, ctx, cache)
    new_cache = _merge_cache(cfg, cache, new_layers, pos + 1, slot_pos)
    return _logits(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# slot-addressable serving (continuous batching)
# ---------------------------------------------------------------------------

def _tag_slots(slot_pos: jax.Array, positions: jax.Array) -> jax.Array:
    """Tag each row's ring slots with this step's absolute positions.
    slot_pos: (B,C), positions: (B,T) -> updated (B,C).  One batched
    scatter (T <= C keeps the slots distinct, like the K/V scatter)."""
    b, t = positions.shape
    c = slot_pos.shape[1]
    rows = jnp.arange(b)[:, None]
    return slot_pos.at[rows, positions % c].set(positions,
                                                unique_indices=True)


def _slots_ctx(cache: dict, positions: jax.Array, mesh) -> tuple[dict, Any]:
    slot_pos = None
    if "slot_pos" in cache:
        slot_pos = _tag_slots(cache["slot_pos"], positions)
    ctx = {"mode": "slots", "pos": cache["pos"], "positions": positions,
           "slot_pos": slot_pos, "enc_out": None, "mesh": mesh}
    return ctx, slot_pos


def decode_slots(params, cfg: ModelConfig, token: jax.Array, cache: dict,
                 mesh=None) -> tuple[jax.Array, dict]:
    """One continuous-batching step: token (B,) against a per-slot cache
    (``CacheSpec(per_slot=True)``: pos (B,), slot_pos (B,C)).  Every slot
    advances by one token at its *own* position -> (logits (B,1,V), cache).
    """
    if cfg.encoder_layers:
        raise ValueError("decode_slots does not support enc-dec models")
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache["pos"]                                  # (B,)
    positions = pos[:, None]                            # (B,1)
    ctx, slot_pos = _slots_ctx(cache, positions, mesh)
    x, new_layers = _run_decoder(params, cfg, x, ctx, cache)
    new_cache = _merge_cache(cfg, cache, new_layers, pos + 1, slot_pos)
    return _logits(params, cfg, x), new_cache


def chunk_prefill(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
                  mesh=None) -> tuple[jax.Array, dict]:
    """Advance a per-slot cache by a chunk of T prompt tokens.

    tokens: (B,T) starting at each row's ``cache["pos"]``.  Attention runs
    against the ring cache (earlier chunks' K/V plus this chunk's, tagged
    by absolute position), so while the prompt fits the ring (total length
    <= C) any chunking — interleaved with other requests' decode steps —
    is bit-identical to a single pass.  Beyond capacity the ring is
    already a sliding-window approximation and a chunk's writes land
    before its tokens attend, so chunk boundaries decide which of the
    oldest in-window keys survive — the same class of approximation as
    lock-step ``prefill`` keeping the last C rows of full attention.
    Returns (last-token logits (B,1,V), cache)."""
    if cfg.encoder_layers:
        raise ValueError("chunk_prefill does not support enc-dec models")
    b, t = tokens.shape
    x = _embed(params, cfg, tokens, None)
    pos = cache["pos"]                                  # (B,)
    positions = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    if "slot_pos" in cache and t > cache["slot_pos"].shape[1]:
        raise ValueError(f"chunk of {t} tokens exceeds cache capacity "
                         f"{cache['slot_pos'].shape[1]}")
    ctx, slot_pos = _slots_ctx(cache, positions, mesh)
    x, new_layers = _run_decoder(params, cfg, x, ctx, cache)
    new_cache = _merge_cache(cfg, cache, new_layers, pos + t, slot_pos)
    return _logits(params, cfg, x[:, -1:, :]), new_cache


def _slot_batch_axis(key: str) -> int:
    """Axis of the request/slot dim in a cache leaf."""
    return 0 if key in ("pos", "slot_pos") else 1


def cache_insert_slot(cache: dict, slot: jax.Array, req_cache: dict) -> dict:
    """Insert a prefilled single-request cache (batch 1) into ``slot`` of a
    per-slot batch cache.  Shapes must agree except the slot/batch dim."""
    out = {}
    for key, buf in cache.items():
        out[key] = lax.dynamic_update_slice_in_dim(
            buf, req_cache[key].astype(buf.dtype), slot,
            axis=_slot_batch_axis(key))
    return out


def cache_extract_slot(cache: dict, slot: jax.Array) -> dict:
    """Inverse of ``cache_insert_slot``: slice one request's batch-1 cache
    (KV ring plus ``pos``/``slot_pos`` metadata) out of a per-slot batch
    cache.  Extract-then-insert round-trips bit-identically — the spill
    path of the serve engine's ``offload_slot``/``refill_slot``."""
    return {key: lax.dynamic_slice_in_dim(buf, slot, 1,
                                          axis=_slot_batch_axis(key))
            for key, buf in cache.items()}


def cache_evict_slot(cache: dict, slot: jax.Array) -> dict:
    """Free a slot: reset its position and mask every ring tag so the stale
    K/V is unreachable.  The buffers themselves are left in place."""
    out = dict(cache)
    out["pos"] = lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.zeros((1,), jnp.int32), slot, axis=0)
    if "slot_pos" in cache:
        c = cache["slot_pos"].shape[1]
        out["slot_pos"] = lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], jnp.full((1, c), _POS_SENTINEL, jnp.int32),
            slot, axis=0)
    return out


def loss_fn(params, cfg: ModelConfig, batch: dict,
            remat: bool = True, mesh=None, layout: str = "tp") -> jax.Array:
    logits = forward_train(params, cfg, batch["tokens"],
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"), remat=remat,
                           mesh=mesh, layout=layout)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:   # vision prefix: score text only
        logits = logits[:, -labels.shape[1]:, :]
    return softmax_xent(logits, labels, batch.get("mask"))
