"""AdamW + schedules, pure JAX, sharding-aware.

* first/second moments can be held in bf16 (``state_dtype``) — the
  distributed-optimization trick that lets the 1T-param kimi-k2 config fit
  512 x 16GB chips (EXPERIMENTS.md §Dry-run discusses the budget).
* ``state_shardings`` mirrors the parameter shardings so ZeRO-1 placement
  (moments sharded over data+model) falls out of the param rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * step / max(cfg.warmup_steps, 1)
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)
    return lr


def init_state(params: PyTree, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(jnp.shape(p), dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_update(params: PyTree, grads: PyTree, state: dict,
                 cfg: AdamWConfig) -> tuple[PyTree, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg)(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd_block(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    def upd(p, g, m, v):
        # trillion-param stacked leaves: chunk the elementwise update over
        # the stacked-blocks axis so the fp32 temporaries stay one block
        # wide (kimi-k2's expert leaves are ~5 GiB/device in fp32)
        if p.ndim >= 3 and p.size * 4 > (1 << 28):
            return jax.lax.map(lambda t: upd_block(*t), (p, g, m, v))
        return upd_block(p, g, m, v)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3 and not isinstance(
        x[0], tuple)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    new_state = {"step": step, "m": newm, "v": newv}
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}


def state_shardings(state: dict, param_shardings: PyTree, mesh,
                    params: PyTree = None) -> dict:
    """ZeRO-1: moments always take the FSDP placement (sharded over data
    *and* model) regardless of how the live params are held — they are only
    touched at the update, so their gathers happen once per step, not per
    microbatch.  The step counter is replicated."""
    from repro.distributed.sharding import param_shardings as psh, replicated
    moments = (psh(params, mesh, fsdp=True) if params is not None
               else param_shardings)
    return {
        "step": replicated(mesh),
        "m": moments,
        "v": moments,
    }
