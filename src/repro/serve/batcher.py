"""Continuous-batching request scheduler over the slot-batched engine.

The lock-step ``ServeEngine.generate`` path serves one static batch: every
request waits for batch formation, prefills together, and decodes until the
*slowest* request finishes.  This module schedules at request granularity
instead (DESIGN.md §6):

* a request **queue** admits work as it arrives;
* requests **prefill in chunks** (``ServeConfig.prefill_chunk`` tokens per
  scheduler tick), interleaved with decode steps of the running batch.
  The admission budget comes from the ELK plan (``elk_serve_config``):
  single-chip plans size it to the gather-ahead window; pipeline-pod plans
  size it to the **steady-state interval** (DESIGN.md §7) — one interval's
  worth of decode work bounds the prefill a tick may inject without
  stalling the pipeline's bottleneck stage;
* a prefilled request is **spliced into a free slot** of the engine's
  per-slot cache and decodes alongside whatever else is running;
* a finished request **leaves its slot immediately** — the next queued
  request takes it over while the others keep decoding.

With a finite backing tier (``ServeConfig.oversub`` = K > 1, DESIGN.md
§11) the batcher **oversubscribes**: up to K× the physical slot count may
be in flight.  Requests admitted while every slot is busy prefill *ahead*
into a slotless cache and park offloaded (host copy = the backing tier);
a refill-ahead hook moves the longest-waiting spilled request into each
slot the moment it frees, and an LRU policy over decode recency
(residency-age tiebreak) swaps a long-running resident out for a starving
waiter — each move priced at ``ServeConfig.slot_spill_s`` and recorded in
``spill_events`` so the event simulator can re-price the same traffic
(``chip.simulator.simulate_kv_traffic``).  Offload→refill round-trips are
bit-identical (slot extract/insert are exact slices), so oversubscribed
greedy output equals running each request alone.

The decode hot loop is one donated ``engine.step`` per tick regardless of
how requests come and go, so throughput tracks slot occupancy instead of
the lock-step batch's worst case.  Greedy outputs are bit-identical to
running each request alone (`tests/test_serve_batcher.py`).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import ServeEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S0,) int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0        # offset from trace start
    tenant: int = 0               # multi-tenant traces: which arrival
    #                               stream this request came from


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray            # (S0 + max_new_tokens,)
    prompt_len: int
    arrival_s: float
    finish_s: float
    finish_order: int
    first_token_s: float = -1.0   # when the first new token appeared
    #                               (-1: degenerate request, no token)
    admitted_s: float = -1.0      # when the request left the queue and
    #                               its prefill began (-1: unknown)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token — what a prefix-cache hit or chunked refill
        actually buys an interactive request."""
        if self.first_token_s < 0:
            return self.latency_s
        return self.first_token_s - self.arrival_s

    @property
    def queue_wait_s(self) -> float:
        """Seconds spent queued before admission — the signal a fleet
        router balances across pods (DESIGN.md §12)."""
        if self.admitted_s < 0:
            return 0.0
        return max(self.admitted_s - self.arrival_s, 0.0)


@dataclasses.dataclass
class _Prefill:
    req: Request
    cache: dict
    off: int                      # prompt tokens already processed
    slot: int                     # reserved destination slot (-1: prefill
    #                               ahead, will park offloaded)


@dataclasses.dataclass
class _Active:
    req: Request
    generated: list
    first_s: float = -1.0         # first-token time (from trace start)
    last_step: int = 0            # tick of the slot's last decode step
    since: int = 0                # tick the request became resident


@dataclasses.dataclass
class _Spilled:
    """A request whose KV ring lives on the backing tier: either prefilled
    ahead of any free slot or swapped out mid-decode by the LRU policy."""
    req: Request
    generated: list
    pending: int                  # next token to feed after refill
    state: dict                   # host-resident slot state (real copies)
    first_s: float
    spilled_at: int               # tick it left (or never entered) a slot
    last_step: int                # decode recency carried across the spill


def _chunk_len(remaining: int, budget: int) -> int:
    """Largest power of two <= min(remaining, budget): bounds the set of
    compiled chunk shapes to O(log budget) for arbitrary prompt lengths."""
    t = 1
    while t * 2 <= min(remaining, budget):
        t *= 2
    return t


class ContinuousBatcher:
    """Drives a ``ServeEngine`` in slot-batched mode.

    ``submit`` enqueues requests; each ``tick`` performs (at most) one
    admission, one prefill chunk, and one decode step over the running
    slots.  ``run`` replays a whole arrival trace to completion.
    """

    def __init__(self, engine: ServeEngine,
                 clock: Callable[[], float] = time.perf_counter, *,
                 oversub: Optional[float] = None,
                 prefix_store=None, swap_after: int = 4,
                 handoff: Optional[Callable] = None):
        self.engine = engine
        self.slots = engine.scfg.slots
        # admission budget: the ELK-sized prefill chunk (gather-ahead window
        # or pipeline steady-state interval, see elk_serve_config).  A chunk
        # larger than the cache capacity would wrap a request's own ring
        # mid-chunk; clamp whatever the config asked for.
        self.chunk_budget = max(1, min(engine.scfg.prefill_chunk,
                                       engine.scfg.cache_capacity))
        # oversubscription (DESIGN.md §11): K from the plan unless the
        # caller pins it; K=1 reproduces the slot-capped scheduler exactly.
        self.oversub = engine.scfg.oversub if oversub is None else oversub
        self.virtual_slots = max(self.slots,
                                 int(round(self.slots * self.oversub)))
        self.swap_after = max(1, swap_after)
        # the plan-funded store rides along with oversubscription; a K=1
        # batcher stays byte-for-byte the PR-8 scheduler unless the caller
        # hands it a store explicitly
        if prefix_store is None and self.oversub > 1.0 \
                and engine.scfg.prefix_cache_bytes > 0:
            from repro.serve.prefix import PrefixStore
            prefix_store = PrefixStore(engine.scfg.prefix_cache_bytes)
        self.prefix = prefix_store
        # fleet migration hook (DESIGN.md §12): when set, a request that
        # finishes prefill is handed off — host state + first token — to
        # the router instead of decoding here (prefill-role pods)
        self.handoff = handoff
        self.clock = clock
        self.queue: deque[Request] = deque()
        self.prefilling: Optional[_Prefill] = None
        self.active: dict[int, _Active] = {}
        self.spilled: dict[int, _Spilled] = {}      # rid -> parked state
        self.free = list(range(self.slots))[::-1]   # pop() -> lowest slot
        self.tokens = np.zeros((self.slots,), np.int32)
        self.completed: list[Completion] = []
        self.ticks = 0
        self.spill_events: list[tuple[str, int]] = []   # (kind, nbytes)
        self.planned_spill_s = 0.0
        self.prefix_hits = 0
        self.prefix_tokens_saved = 0
        self._ring_bytes = 0
        self._admitted: dict[int, float] = {}   # rid -> admission time
        # per-tick work counters, read by the fleet's virtual clock to
        # price the tick (reset at the top of every tick)
        self.tick_prefill_tokens = 0
        self.tick_decoded = False
        self.t0 = self.clock()

    # -- scheduling --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt (the first "
                             "generated token is seeded by prefill)")
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.prefilling or self.active
                    or self.spilled)

    def _now(self) -> float:
        return self.clock() - self.t0

    def _finish(self, req: Request, new_tokens: list,
                first_s: float = -1.0) -> None:
        toks = np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(new_tokens, np.int32)])
        self.completed.append(Completion(
            rid=req.rid, tokens=toks, prompt_len=len(req.prompt),
            arrival_s=req.arrival_s, finish_s=self._now(),
            finish_order=len(self.completed), first_token_s=first_s,
            admitted_s=self._admitted.pop(req.rid, -1.0)))

    def _charge(self, kind: str) -> None:
        """Record one ring move across the tier boundary, accumulating the
        plan-priced cost (the simulator re-prices the same event list)."""
        if not self._ring_bytes:
            self._ring_bytes = self.engine.slot_state_bytes()
        self.spill_events.append((kind, self._ring_bytes))
        self.planned_spill_s += self.engine.scfg.slot_spill_s

    def _admit(self) -> None:
        while self.queue and self.queue[0].max_new_tokens <= 0:
            req = self.queue.popleft()
            self._admitted[req.rid] = self._now()
            self._finish(req, [])
        if self.prefilling is not None or not self.queue:
            return
        inflight = len(self.active) + len(self.spilled)
        if self.free:
            slot = self.free.pop()
        elif self.oversub > 1.0 and inflight < self.virtual_slots:
            slot = -1       # prefill ahead; the finished ring parks spilled
        else:
            return
        req = self.queue.popleft()
        self._admitted[req.rid] = self._now()
        cache, off = self.engine.new_request_cache(), 0
        if self.prefix is not None:
            hit = self.prefix.lookup(
                req.prompt, max_len=min(len(req.prompt) - 1,
                                        self.engine.scfg.cache_capacity))
            if hit is not None:
                off, state = hit
                # restore = one refill off the backing tier; jnp.array in
                # refill/prefill copies, so the stored state stays intact
                cache = jax.tree.map(lambda a: jnp.array(a), state)
                self._charge("refill")
                self.prefix_hits += 1
                self.prefix_tokens_saved += off
        self.prefilling = _Prefill(req=req, cache=cache, off=off, slot=slot)

    def _prefill_tick(self) -> None:
        ps = self.prefilling
        if ps is None:
            return
        t = _chunk_len(len(ps.req.prompt) - ps.off, self.chunk_budget)
        chunk = jnp.asarray(
            ps.req.prompt[None, ps.off:ps.off + t], jnp.int32)
        tok, ps.cache = self.engine.prefill_chunk(ps.cache, chunk)
        ps.off += t
        self.tick_prefill_tokens = t
        if ps.off < len(ps.req.prompt):
            # snapshot at the chunk boundary: a strict in-capacity prefix
            # whose ring has never wrapped — the prefix store's unit of
            # reuse (np.array = real host copies of donated buffers)
            if (self.prefix is not None
                    and ps.off <= self.engine.scfg.cache_capacity):
                self.prefix.put(ps.req.prompt[:ps.off],
                                jax.tree.map(lambda a: np.array(a),
                                             ps.cache))
            return
        first = int(tok[0])
        now = self._now()
        if ps.req.max_new_tokens == 1:      # no decode needed
            self._finish(ps.req, [first], first_s=now)
            if ps.slot >= 0:
                self.free.append(ps.slot)
        elif self.handoff is not None:
            # prefill-role pod (DESIGN.md §12): the finished prefill leaves
            # for a decode pod — host-copy the ring (one charged offload)
            # and let the router price the inter-pod leg
            state = jax.tree.map(lambda a: np.array(a), ps.cache)
            self._charge("spill")
            if ps.slot >= 0:
                self.free.append(ps.slot)
            self.handoff(ps.req, state, [first], now,
                         self._admitted.pop(ps.req.rid, -1.0))
        elif ps.slot >= 0:
            self.engine.insert_slot(ps.slot, ps.cache)
            self.active[ps.slot] = _Active(
                req=ps.req, generated=[first], first_s=now,
                last_step=self.ticks, since=self.ticks)
            self.tokens[ps.slot] = first
        else:
            # prefilled ahead of any free slot: park on the backing tier
            state = jax.tree.map(lambda a: np.array(a), ps.cache)
            self.spilled[ps.req.rid] = _Spilled(
                req=ps.req, generated=[first], pending=first, state=state,
                first_s=now, spilled_at=self.ticks, last_step=self.ticks)
            self._charge("spill")
        self.prefilling = None

    def _lru_waiter(self) -> int:
        """rid of the spilled request to refill next: least-recently
        decoded, then longest parked."""
        return min(self.spilled,
                   key=lambda r: (self.spilled[r].last_step,
                                  self.spilled[r].spilled_at, r))

    def _maybe_swap(self) -> None:
        """LRU eviction over decode recency: when a spilled request has
        waited >= ``swap_after`` ticks and no slot is free, offload the
        least-recently-stepped resident (ties: longest resident) so the
        waiter gets its turn — time-slicing K virtual streams over the
        physical slots without starving any of them."""
        if self.free or not self.spilled or not self.active:
            return
        sp = self.spilled[self._lru_waiter()]
        if self.ticks - sp.spilled_at < self.swap_after:
            return
        victim = min(self.active,
                     key=lambda s: (self.active[s].last_step,
                                    self.active[s].since, s))
        va = self.active[victim]
        if self.ticks - va.since < self.swap_after:
            return          # every resident is fresher than one timeslice
        state = self.engine.offload_slot(victim)
        self._charge("spill")
        self.spilled[va.req.rid] = _Spilled(
            req=va.req, generated=va.generated,
            pending=int(self.tokens[victim]), state=state,
            first_s=va.first_s, spilled_at=self.ticks,
            last_step=va.last_step)
        del self.active[victim]
        self.free.append(victim)

    def _refill_tick(self) -> None:
        """Refill-ahead: spilled requests take freed slots before any new
        admission — a refill resumes decode this very tick, while a fresh
        admission still has its whole prefill in front of it."""
        self._maybe_swap()
        while self.free and self.spilled:
            sp = self.spilled.pop(self._lru_waiter())
            slot = self.free.pop()
            self.engine.refill_slot(slot, sp.state)
            self._charge("refill")
            self.active[slot] = _Active(
                req=sp.req, generated=sp.generated, first_s=sp.first_s,
                last_step=self.ticks, since=self.ticks)
            self.tokens[slot] = sp.pending

    def _decode_tick(self) -> None:
        if not self.active:
            return
        self.tick_decoded = True
        nxt = np.asarray(self.engine.step(jnp.asarray(self.tokens)))
        self.tokens = nxt.copy()
        for slot in sorted(self.active):
            st = self.active[slot]
            st.last_step = self.ticks
            st.generated.append(int(nxt[slot]))
            if len(st.generated) >= st.req.max_new_tokens:
                self._finish(st.req, st.generated, first_s=st.first_s)
                self.engine.evict_slot(slot)
                del self.active[slot]
                self.free.append(slot)

    def tick(self) -> None:
        """One scheduler step: refill spilled work into freed slots, admit,
        advance one prefill chunk, decode."""
        self.tick_prefill_tokens = 0
        self.tick_decoded = False
        self._refill_tick()
        self._admit()
        self._prefill_tick()
        self._decode_tick()
        self.ticks += 1

    def adopt(self, req: Request, state: dict, generated: list,
              first_s: float, *, admitted_s: float = -1.0) -> None:
        """Take over a request mid-stream (fleet migration, DESIGN.md §12).

        ``state`` is a host-resident slot state — the other pod's
        ``handoff`` payload or an ``offload_slot`` result — which parks on
        this pod's backing tier and is slotted by the ordinary refill-ahead
        path (the refill move is charged there; the offload was charged
        where the state came from).  ``generated`` must hold at least the
        prefill's first token: its last entry is the next token to feed."""
        if not generated:
            raise ValueError(f"request {req.rid}: nothing generated yet — "
                             "adopt() resumes a stream, prefill seeds it")
        self._admitted[req.rid] = admitted_s
        self.spilled[req.rid] = _Spilled(
            req=req, generated=list(generated),
            pending=int(generated[-1]), state=state, first_s=first_s,
            spilled_at=self.ticks, last_step=self.ticks)

    # -- trace replay ------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Completion]:
        """Replay an arrival trace to completion; returns completions in
        finish order (not arrival order)."""
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        self.t0 = self.clock()
        while pending or self.busy:
            now = self.clock() - self.t0
            while pending and pending[0].arrival_s <= now:
                self.submit(pending.pop(0))
            if not self.busy:
                time.sleep(min(pending[0].arrival_s - now, 0.01))
                continue
            self.tick()
        return self.completed


# ---------------------------------------------------------------------------
# static-batching baseline + trace tooling (shared by bench and launcher)
# ---------------------------------------------------------------------------

def run_static_trace(engine: ServeEngine, requests: list[Request],
                     clock: Callable[[], float] = time.perf_counter
                     ) -> list[Completion]:
    """Lock-step baseline: requests batch up in arrival order; each batch
    left-pads prompts to its longest and decodes until its slowest request
    is done (``generate`` with the batch-max step count).

    This is a *cost* baseline (what a static server pays in padded prefill
    and batch-max decode steps), not a parity path: ``generate`` has no
    padding mask, so in a mixed-length batch the pad tokens leak into a
    request's context and its tokens can differ from serving it alone.
    Bit-identical greedy parity is asserted between the continuous path
    and unpadded lock-step ``generate`` (tests/test_serve_batcher.py)."""
    bsz = engine.scfg.batch
    order = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    out: list[Completion] = []
    t0 = clock()
    for i in range(0, len(order), bsz):
        batch = order[i:i + bsz]
        while clock() - t0 < max(r.arrival_s for r in batch):
            time.sleep(0.001)
        smax = max(len(r.prompt) for r in batch)
        steps = max(r.max_new_tokens for r in batch)
        prompts = np.zeros((bsz, smax), np.int32)
        for j, r in enumerate(batch):
            prompts[j, smax - len(r.prompt):] = r.prompt
        toks = np.asarray(engine.generate(jnp.asarray(prompts), steps=steps))
        finish = clock() - t0
        for j, r in enumerate(batch):
            out.append(Completion(
                rid=r.rid,
                tokens=np.concatenate([
                    np.asarray(r.prompt, np.int32),
                    toks[j, smax:smax + r.max_new_tokens].astype(np.int32)]),
                prompt_len=len(r.prompt),
                arrival_s=r.arrival_s, finish_s=finish,
                finish_order=len(out),
                # lock-step emits the whole stream at batch completion: the
                # first token is only observable when the batch returns
                first_token_s=finish))
    return out


def make_trace(n: int, *, vocab_size: int, prompt_lens=(8, 12, 20, 32),
               max_new=(4, 8, 16, 24), arrival_spacing_s: float = 0.0,
               seed: int = 0, burst: int = 1, sys_prompt_len: int = 0,
               sys_prompt_frac: float = 0.0, tenant_rates=(),
               tail_frac: float = 0.0,
               tail_mult: float = 4.0) -> list[Request]:
    """Mixed-length request trace: prompts/output budgets cycle through the
    given grids out of phase, arrivals optionally staggered.

    ``burst`` > 1 makes arrivals bursty: requests land in groups of
    ``burst`` sharing one arrival time, groups ``arrival_spacing_s``
    apart.  ``sys_prompt_len``/``sys_prompt_frac`` prepend a shared
    "system prompt" of that length to the given fraction of prompts — the
    traffic shape prefix reuse feeds on.

    Multi-tenant knobs (DESIGN.md §12): ``tenant_rates`` is a tuple of
    relative arrival rates — each request is labeled with a tenant drawn
    proportionally to its rate and arrivals become a merged Poisson
    process with mean inter-arrival ``arrival_spacing_s`` (the merge of
    per-tenant Poisson streams *is* one Poisson stream whose tenant labels
    follow the rate shares, so this models K tenants exactly).
    ``tail_frac`` makes prompt lengths heavy-tailed: that fraction of
    requests stretch their grid length by a Pareto(2) factor, capped at
    ``tail_mult``x.  Everything is keyed off ``seed``, and the default
    arguments reproduce the old traces byte-identically (every new knob
    draws from its own substream)."""
    rng = np.random.default_rng(seed)
    burst = max(1, burst)
    sys_prompt = None
    pick = None
    if sys_prompt_len > 0 and sys_prompt_frac > 0:
        sys_prompt = np.random.default_rng(seed + 1).integers(
            0, vocab_size, size=(sys_prompt_len,), dtype=np.int32)
        pick = np.random.default_rng(seed + 2)
    tenants = arrivals = None
    if len(tenant_rates) > 0:
        rates = np.asarray(tenant_rates, float)
        if rates.min() <= 0:
            raise ValueError(f"tenant_rates must be positive: {tenant_rates}")
        trng = np.random.default_rng(seed + 3)
        tenants = trng.choice(len(rates), size=n, p=rates / rates.sum())
        arrivals = np.cumsum(trng.exponential(
            scale=max(arrival_spacing_s, 0.0), size=n))
    tail = np.random.default_rng(seed + 4) if tail_frac > 0 else None
    reqs = []
    for i in range(n):
        s0 = prompt_lens[i % len(prompt_lens)]
        if tail is not None and tail.random() < tail_frac:
            s0 = min(int(s0 * (1.0 + tail.pareto(2.0))),
                     int(s0 * max(tail_mult, 1.0)))
        prompt = rng.integers(0, vocab_size, size=(s0,), dtype=np.int32)
        if sys_prompt is not None and pick.random() < sys_prompt_frac:
            prompt = np.concatenate([sys_prompt, prompt])
        reqs.append(Request(
            rid=i, prompt=prompt,
            max_new_tokens=max_new[(i * 3 + 1) % len(max_new)],
            arrival_s=(float(arrivals[i]) if arrivals is not None
                       else (i // burst) * arrival_spacing_s),
            tenant=int(tenants[i]) if tenants is not None else 0))
    return reqs


def summarize(completions: list[Completion], wall_s: float) -> dict:
    """Throughput (generated tokens only) + latency/TTFT percentiles, plus
    the per-phase signals a fleet router needs (DESIGN.md §12): queue-wait
    percentiles and the steady decode token rate over the span between the
    first token anywhere and the last finish.  New keys only — existing
    consumers of the bench JSON see the same keys as before."""
    lats = np.asarray([c.latency_s for c in completions])
    ttfts = np.asarray([c.ttft_s for c in completions])
    waits = np.asarray([c.queue_wait_s for c in completions])
    gen = sum(len(c.tokens) - c.prompt_len for c in completions)
    firsts = [c.first_token_s for c in completions if c.first_token_s >= 0]
    span = (max(c.finish_s for c in completions) - min(firsts)) \
        if firsts else 0.0
    return {
        "requests": len(completions),
        "wall_s": round(wall_s, 4),
        "gen_tok_s": 0.0 if wall_s <= 0 else round(gen / wall_s, 2),
        "p50_latency_s": round(float(np.percentile(lats, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lats, 99)), 4),
        "p50_ttft_s": round(float(np.percentile(ttfts, 50)), 4),
        "p99_ttft_s": round(float(np.percentile(ttfts, 99)), 4),
        "p50_queue_wait_s": round(float(np.percentile(waits, 50)), 4),
        "p99_queue_wait_s": round(float(np.percentile(waits, 99)), 4),
        "decode_tok_s": round(gen / span, 2) if span > 0 else (
            0.0 if wall_s <= 0 else round(gen / wall_s, 2)),
    }
