"""Continuous-batching request scheduler over the slot-batched engine.

The lock-step ``ServeEngine.generate`` path serves one static batch: every
request waits for batch formation, prefills together, and decodes until the
*slowest* request finishes.  This module schedules at request granularity
instead (DESIGN.md §6):

* a request **queue** admits work as it arrives;
* requests **prefill in chunks** (``ServeConfig.prefill_chunk`` tokens per
  scheduler tick), interleaved with decode steps of the running batch.
  The admission budget comes from the ELK plan (``elk_serve_config``):
  single-chip plans size it to the gather-ahead window; pipeline-pod plans
  size it to the **steady-state interval** (DESIGN.md §7) — one interval's
  worth of decode work bounds the prefill a tick may inject without
  stalling the pipeline's bottleneck stage;
* a prefilled request is **spliced into a free slot** of the engine's
  per-slot cache and decodes alongside whatever else is running;
* a finished request **leaves its slot immediately** — the next queued
  request takes it over while the others keep decoding.

The decode hot loop is one donated ``engine.step`` per tick regardless of
how requests come and go, so throughput tracks slot occupancy instead of
the lock-step batch's worst case.  Greedy outputs are bit-identical to
running each request alone (`tests/test_serve_batcher.py`).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import ServeEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S0,) int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0        # offset from trace start


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray            # (S0 + max_new_tokens,)
    prompt_len: int
    arrival_s: float
    finish_s: float
    finish_order: int

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class _Prefill:
    req: Request
    cache: dict
    off: int                      # prompt tokens already processed
    slot: int                     # reserved destination slot


@dataclasses.dataclass
class _Active:
    req: Request
    generated: list


def _chunk_len(remaining: int, budget: int) -> int:
    """Largest power of two <= min(remaining, budget): bounds the set of
    compiled chunk shapes to O(log budget) for arbitrary prompt lengths."""
    t = 1
    while t * 2 <= min(remaining, budget):
        t *= 2
    return t


class ContinuousBatcher:
    """Drives a ``ServeEngine`` in slot-batched mode.

    ``submit`` enqueues requests; each ``tick`` performs (at most) one
    admission, one prefill chunk, and one decode step over the running
    slots.  ``run`` replays a whole arrival trace to completion.
    """

    def __init__(self, engine: ServeEngine,
                 clock: Callable[[], float] = time.perf_counter):
        self.engine = engine
        self.slots = engine.scfg.slots
        # admission budget: the ELK-sized prefill chunk (gather-ahead window
        # or pipeline steady-state interval, see elk_serve_config).  A chunk
        # larger than the cache capacity would wrap a request's own ring
        # mid-chunk; clamp whatever the config asked for.
        self.chunk_budget = max(1, min(engine.scfg.prefill_chunk,
                                       engine.scfg.cache_capacity))
        self.clock = clock
        self.queue: deque[Request] = deque()
        self.prefilling: Optional[_Prefill] = None
        self.active: dict[int, _Active] = {}
        self.free = list(range(self.slots))[::-1]   # pop() -> lowest slot
        self.tokens = np.zeros((self.slots,), np.int32)
        self.completed: list[Completion] = []
        self.t0 = self.clock()

    # -- scheduling --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt (the first "
                             "generated token is seeded by prefill)")
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.prefilling or self.active)

    def _finish(self, req: Request, new_tokens: list) -> None:
        toks = np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(new_tokens, np.int32)])
        self.completed.append(Completion(
            rid=req.rid, tokens=toks, prompt_len=len(req.prompt),
            arrival_s=req.arrival_s, finish_s=self.clock() - self.t0,
            finish_order=len(self.completed)))

    def _admit(self) -> None:
        while self.queue and self.queue[0].max_new_tokens <= 0:
            self._finish(self.queue.popleft(), [])
        if self.prefilling is None and self.queue and self.free:
            req = self.queue.popleft()
            self.prefilling = _Prefill(
                req=req, cache=self.engine.new_request_cache(), off=0,
                slot=self.free.pop())

    def _prefill_tick(self) -> None:
        ps = self.prefilling
        if ps is None:
            return
        t = _chunk_len(len(ps.req.prompt) - ps.off, self.chunk_budget)
        chunk = jnp.asarray(
            ps.req.prompt[None, ps.off:ps.off + t], jnp.int32)
        tok, ps.cache = self.engine.prefill_chunk(ps.cache, chunk)
        ps.off += t
        if ps.off < len(ps.req.prompt):
            return
        first = int(tok[0])
        if ps.req.max_new_tokens == 1:      # no decode needed
            self._finish(ps.req, [first])
            self.free.append(ps.slot)
        else:
            self.engine.insert_slot(ps.slot, ps.cache)
            self.active[ps.slot] = _Active(req=ps.req, generated=[first])
            self.tokens[ps.slot] = first
        self.prefilling = None

    def _decode_tick(self) -> None:
        if not self.active:
            return
        nxt = np.asarray(self.engine.step(jnp.asarray(self.tokens)))
        self.tokens = nxt.copy()
        for slot in sorted(self.active):
            st = self.active[slot]
            st.generated.append(int(nxt[slot]))
            if len(st.generated) >= st.req.max_new_tokens:
                self._finish(st.req, st.generated)
                self.engine.evict_slot(slot)
                del self.active[slot]
                self.free.append(slot)

    def tick(self) -> None:
        """One scheduler step: admit, advance one prefill chunk, decode."""
        self._admit()
        self._prefill_tick()
        self._decode_tick()

    # -- trace replay ------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Completion]:
        """Replay an arrival trace to completion; returns completions in
        finish order (not arrival order)."""
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        self.t0 = self.clock()
        while pending or self.busy:
            now = self.clock() - self.t0
            while pending and pending[0].arrival_s <= now:
                self.submit(pending.pop(0))
            if not self.busy:
                time.sleep(min(pending[0].arrival_s - now, 0.01))
                continue
            self.tick()
        return self.completed


# ---------------------------------------------------------------------------
# static-batching baseline + trace tooling (shared by bench and launcher)
# ---------------------------------------------------------------------------

def run_static_trace(engine: ServeEngine, requests: list[Request],
                     clock: Callable[[], float] = time.perf_counter
                     ) -> list[Completion]:
    """Lock-step baseline: requests batch up in arrival order; each batch
    left-pads prompts to its longest and decodes until its slowest request
    is done (``generate`` with the batch-max step count).

    This is a *cost* baseline (what a static server pays in padded prefill
    and batch-max decode steps), not a parity path: ``generate`` has no
    padding mask, so in a mixed-length batch the pad tokens leak into a
    request's context and its tokens can differ from serving it alone.
    Bit-identical greedy parity is asserted between the continuous path
    and unpadded lock-step ``generate`` (tests/test_serve_batcher.py)."""
    bsz = engine.scfg.batch
    order = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    out: list[Completion] = []
    t0 = clock()
    for i in range(0, len(order), bsz):
        batch = order[i:i + bsz]
        while clock() - t0 < max(r.arrival_s for r in batch):
            time.sleep(0.001)
        smax = max(len(r.prompt) for r in batch)
        steps = max(r.max_new_tokens for r in batch)
        prompts = np.zeros((bsz, smax), np.int32)
        for j, r in enumerate(batch):
            prompts[j, smax - len(r.prompt):] = r.prompt
        toks = np.asarray(engine.generate(jnp.asarray(prompts), steps=steps))
        finish = clock() - t0
        for j, r in enumerate(batch):
            out.append(Completion(
                rid=r.rid,
                tokens=np.concatenate([
                    np.asarray(r.prompt, np.int32),
                    toks[j, smax:smax + r.max_new_tokens].astype(np.int32)]),
                prompt_len=len(r.prompt),
                arrival_s=r.arrival_s, finish_s=finish,
                finish_order=len(out)))
    return out


def make_trace(n: int, *, vocab_size: int, prompt_lens=(8, 12, 20, 32),
               max_new=(4, 8, 16, 24), arrival_spacing_s: float = 0.0,
               seed: int = 0) -> list[Request]:
    """Mixed-length request trace: prompts/output budgets cycle through the
    given grids out of phase, arrivals optionally staggered."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        s0 = prompt_lens[i % len(prompt_lens)]
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab_size, size=(s0,), dtype=np.int32),
            max_new_tokens=max_new[(i * 3 + 1) % len(max_new)],
            arrival_s=i * arrival_spacing_s))
    return reqs


def summarize(completions: list[Completion], wall_s: float) -> dict:
    """Throughput (generated tokens only) + latency percentiles."""
    lats = np.asarray([c.latency_s for c in completions])
    gen = sum(len(c.tokens) - c.prompt_len for c in completions)
    return {
        "requests": len(completions),
        "wall_s": round(wall_s, 4),
        "gen_tok_s": 0.0 if wall_s <= 0 else round(gen / wall_s, 2),
        "p50_latency_s": round(float(np.percentile(lats, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lats, 99)), 4),
    }
