"""Batched serving engine: prefill + decode with sharded KV caches.

Two execution modes:

* ``gspmd``      — weights resident replicated-over-data / TP-sharded;
  XLA schedules all collectives (the `Basic`-like baseline at pod level).
* ``elk_stream`` — weights resident *sharded over data* (ELK preload
  state, 1/k per device) with the gather-ahead window of
  ``serve/stream.py``; prefetch depth comes from the ELK scheduler via
  ``core/integration.pod_plan``.  This is what lets a model k-times larger
  than one replica's HBM serve from the pod, at the cost of ICI traffic —
  the paper's capacity/IO/communication trade, live.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (batch_axes, batch_shardings,
                                        cache_shardings, param_shardings,
                                        replicated)
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.frontends import frontend_embeddings

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    batch: int
    cache_capacity: int
    mode: str = "gspmd"               # gspmd | elk_stream
    prefetch_depth: int = 2           # ELK preload number (elk_stream)
    kv_dtype: str = "bfloat16"        # bfloat16 | int8


def elk_serve_config(cfg: ModelConfig, *, batch: int, cache_capacity: int,
                     kv_dtype: str = "bfloat16", num_chips: int = 256,
                     design: str = "ELK-Full") -> ServeConfig:
    """ServeConfig with the prefetch depth chosen by the ELK scheduler.

    ``pod_plan`` reads the process-level plan cache (DESIGN.md §2), so this
    is cheap to call per engine/request once any compile for the same
    (model, shape, design) has happened in this process.
    """
    from repro.core.integration import pod_plan

    knobs = pod_plan(cfg, batch=batch, seq=cache_capacity, phase="decode",
                     num_chips=num_chips, design=design)
    return ServeConfig(batch=batch, cache_capacity=cache_capacity,
                       mode="elk_stream",
                       prefetch_depth=max(knobs.prefetch_depth, 1),
                       kv_dtype=kv_dtype)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, params: PyTree,
                 scfg: ServeConfig):
        self.cfg = cfg
        self.mesh = mesh
        self.scfg = scfg
        fsdp = scfg.mode == "elk_stream"
        self.p_sh = param_shardings(params, mesh, fsdp=fsdp)
        self.params = jax.device_put(params, self.p_sh)

        cache = tfm.init_cache(cfg, tfm.CacheSpec(
            capacity=scfg.cache_capacity, batch=scfg.batch,
            kv_dtype=jnp.dtype(scfg.kv_dtype)))
        self.c_sh = cache_shardings(cache, mesh)
        self.cache0 = jax.device_put(cache, self.c_sh)

        bp = batch_axes(mesh)
        tok_sh = NamedSharding(mesh, P(bp))
        logit_sh = NamedSharding(mesh, P(bp, None, "model"))

        if scfg.mode == "elk_stream":
            from repro.serve.stream import streaming_decode_step

            def decode(params, token, cache):
                return streaming_decode_step(params, cfg, token, cache,
                                             mesh=mesh,
                                             prefetch=scfg.prefetch_depth)
        else:
            def decode(params, token, cache):
                return tfm.decode_step(params, cfg, token, cache)

        self._decode = jax.jit(
            decode,
            in_shardings=(self.p_sh, tok_sh, self.c_sh),
            out_shardings=(logit_sh, self.c_sh),
        )

        def prefill(params, tokens, cache, embeds=None, enc_embeds=None):
            kw = {}
            if embeds is not None:
                kw["embeds"] = embeds
            if enc_embeds is not None:
                kw["enc_embeds"] = enc_embeds
            return tfm.prefill(params, cfg, tokens, cache, **kw)

        self._prefill = jax.jit(prefill)

    # -- public API --------------------------------------------------------
    def prefill(self, tokens: jax.Array, cache: Optional[dict] = None,
                **frontends) -> tuple[jax.Array, dict]:
        cache = cache if cache is not None else self.cache0
        return self._prefill(self.params, tokens, cache,
                             frontends.get("embeds"),
                             frontends.get("enc_embeds"))

    def decode(self, token: jax.Array, cache: dict
               ) -> tuple[jax.Array, dict]:
        return self._decode(self.params, token, cache)

    def generate(self, prompts: jax.Array, steps: int,
                 greedy: bool = True) -> jax.Array:
        """prompts: (B, S0) -> (B, S0 + steps) greedy continuation."""
        fe = frontend_embeddings(self.cfg, prompts.shape[0])
        logits, cache = self.prefill(prompts, **fe)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [prompts, tok[:, None]]
        for _ in range(steps - 1):
            logits, cache = self.decode(tok, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out.append(tok[:, None])
        return jnp.concatenate(out, axis=1)
