"""Batched serving engine: prefill + decode with sharded KV caches.

Two execution modes:

* ``gspmd``      — weights resident replicated-over-data / TP-sharded;
  XLA schedules all collectives (the `Basic`-like baseline at pod level).
* ``elk_stream`` — weights resident *sharded over data* (ELK preload
  state, 1/k per device) with the gather-ahead window of
  ``serve/stream.py``; prefetch depth comes from the ELK scheduler via
  ``core/integration.pod_plan``.  This is what lets a model k-times larger
  than one replica's HBM serve from the pod, at the cost of ICI traffic —
  the paper's capacity/IO/communication trade, live.

Two batching disciplines (DESIGN.md §6):

* lock-step      — ``generate``: one static batch, every request advances
  together.
* slot-batched   — ``step``/``prefill_chunk``/``insert_slot``/
  ``evict_slot``: a per-slot cache where every row sits at its own
  position; requests join/leave the running batch at slot granularity.
  ``serve/batcher.py`` drives this as a continuous-batching scheduler.

Every jitted step that threads a cache **donates** it: the compiled step
aliases the cache input to the cache output (no per-token copy, no double
HBM footprint), exactly as ``train/step.py`` donates params/opt state.
Callers must treat a cache passed to the engine as consumed and use the
returned one.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (batch_axes, cache_shardings,
                                        param_shardings)
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.frontends import frontend_embeddings

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    batch: int
    cache_capacity: int
    mode: str = "gspmd"               # gspmd | elk_stream
    prefetch_depth: int = 2           # ELK preload number (elk_stream)
    kv_dtype: str = "bfloat16"        # bfloat16 | int8
    max_slots: int = 0                # continuous batching slots (0 = batch)
    prefill_chunk: int = 32           # max prompt tokens per scheduler tick
    steady_interval_s: float = 0.0    # pipeline-pod steady-state interval
    #                                   (0 = single-chip plan, no pipeline)
    oversub: float = 1.0              # admission multiplier K: virtual slots
    #                                   per physical slot (DESIGN.md §11);
    #                                   >1 only with a finite backing tier
    slot_spill_s: float = 0.0         # planned one-way offload/refill time
    #                                   for one slot's KV ring (spill_time)
    prefix_cache_bytes: int = 0       # prefix-KV store budget in the bytes
    #                                   left after rings (0 = store off)

    @property
    def slots(self) -> int:
        return self.max_slots or self.batch

    @property
    def virtual_slots(self) -> int:
        """Requests the batcher may hold in flight: the physical slots plus
        the spilled rings the backing tier can park (``oversub`` = K)."""
        return max(self.slots, int(round(self.slots * self.oversub)))


def tier_kv_capacity(cfg: ModelConfig, chip, *, batch: int,
                     kv_dtype: str = "bfloat16") -> int:
    """Per-request KV-cache tokens resident in ``chip``'s off-core memory
    tiers after weight placement (DESIGN.md §10), or 0 when unbounded.

    The KV cache lives in the chip's non-SRAM tiers.  A chip with an
    unbounded backing store (any ``hbm_bw > 0`` chip — including every
    default two-tier config) can hold any cache length, so the budget is
    infinite and this returns 0 ("no cap").  On an SRAM-only chip with
    finite staging tiers (e.g. ``ipu_pod4().with_stacked_dram()``), the
    stacked bytes left after the weights that spill out of SRAM bound the
    cache:  ``tokens = (tier_bytes - weight_spill) // (batch * per_token)``
    with ``per_token = num_layers * 2 * num_kv_heads * head_dim *
    itemsize(kv_dtype)``.
    """
    if chip is None:
        return 0
    tiers = chip.mem_tiers[1:]
    if not tiers or any(t.unbounded for t in tiers):
        return 0
    left = _tier_bytes_left(cfg, chip)
    hd = cfg.resolved_head_dim
    per_token = (cfg.num_layers * 2 * cfg.num_kv_heads * hd
                 * jnp.dtype(kv_dtype).itemsize)
    return int(left // max(batch * per_token, 1))


def _tier_bytes_left(cfg: ModelConfig, chip) -> int:
    """Off-core tier bytes left after weight placement.  ``place_tiers``
    stages the weights that spill out of SRAM across the finite tiers
    (staging tiers + backing store); the *aggregate* bytes they occupy are
    placement-invariant, so the KV budget is the summed tier capacity minus
    that spill regardless of which tier each block landed in."""
    budget = sum(t.capacity for t in chip.mem_tiers[1:])
    weight_bytes = cfg.param_count() * jnp.dtype(cfg.param_dtype).itemsize
    spill = max(0, weight_bytes - chip.total_sram)
    return budget - min(spill, budget)


def kv_ring_bytes(cfg: ModelConfig, capacity: int,
                  kv_dtype: str = "bfloat16") -> int:
    """Bytes one request's spillable slot state occupies: the KV ring plus
    ``pos``/``slot_pos`` metadata — the volume one ``offload_slot`` /
    ``refill_slot`` moves across the tier boundary."""
    try:
        spec = tfm.CacheSpec(capacity=capacity, batch=1,
                             kv_dtype=jnp.dtype(kv_dtype), per_slot=True)
        shape = jax.eval_shape(lambda: tfm.init_cache(cfg, spec))
        return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(shape)))
    except ValueError:      # enc-dec: no per-slot serving; K/V formula only
        hd = cfg.resolved_head_dim
        return (capacity * cfg.num_layers * 2 * cfg.num_kv_heads * hd
                * jnp.dtype(kv_dtype).itemsize)


_OVERSUB_MAX = 8.0   # spill-pool backstop: at most 8 virtual slots/physical


def tier_kv_oversub(cfg: ModelConfig, chip, *, slots: int,
                    cache_capacity: int,
                    kv_dtype: str = "bfloat16") -> float:
    """Admission multiplier K for the oversubscribed batcher (DESIGN.md
    §11): how many full KV rings the tier bytes left after weight placement
    can hold, per physical slot.  1.0 when any backing tier is unbounded
    (nothing forces a spill — the resident cache can simply grow) or when
    the budget holds no more rings than the resident slots."""
    if chip is None:
        return 1.0
    tiers = chip.mem_tiers[1:]
    if not tiers or any(t.unbounded for t in tiers):
        return 1.0
    ring = kv_ring_bytes(cfg, cache_capacity, kv_dtype)
    rings = _tier_bytes_left(cfg, chip) // max(ring, 1)
    return float(max(1.0, min(rings / max(slots, 1), _OVERSUB_MAX)))


PREFILL_SAT = 128   # prompt tokens one weight pass saturates: the chunk
#                     size past which prefill stops being amortized (the
#                     planner's long-standing chunk clamp, now named)


def elk_serve_config(cfg: ModelConfig, *, batch: int, cache_capacity: int,
                     kv_dtype: str = "bfloat16", num_chips: int = 256,
                     design: str = "ELK-Full", pipeline: bool = False,
                     pod=None, role: str = "mixed") -> ServeConfig:
    """ServeConfig with the serving knobs chosen by the ELK scheduler.

    ``pod_plan`` reads the process-level plan cache (DESIGN.md §2), so this
    is cheap to call per engine/request once any compile for the same
    (model, shape, design) has happened in this process.

    * ``prefetch_depth`` — the paper's preload number p, per layer-block.
    * ``prefill_chunk``  — admission budget for chunked prefill: how many
      prompt tokens one scheduler tick may process.

      Single-chip plans size it to the gather-ahead window (16 tokens of
      chunk compute per preloaded block keeps the chunk hidden behind the
      window's ICI traffic).  With ``pipeline=True`` the pod is planned
      with the **hybrid** search (joint cut x width x replicas x
      microbatch, DESIGN.md §9 — never worse than pure pipeline stages)
      and admission is sized from the **steady-state interval** instead:
      the whole running batch decodes once per ``batch_interval``, so one
      interval hides up to ``microbatch * microbatches`` prompt tokens of
      prefill — that is the per-tick admission budget (for a pure
      pipeline plan ``microbatches == num_stages``, so this is the same
      budget as before the hybrid search existed).  Both are clamped to
      the cache capacity so one chunk never wraps a request's own ring.

    ``role`` specializes the sizing for a disaggregated fleet pod
    (DESIGN.md §12).  A ``prefill`` pod's whole job is admission, so its
    chunk budget opens to the full saturating weight pass
    (``PREFILL_SAT`` tokens) instead of the interference-limited budget a
    mixed pod must respect.  A ``decode`` pod receives its work
    pre-filled over the fleet tier and spends its budget on residency
    instead: the chunk shrinks to the floor (it only prefills work shed
    to it) while the plan's full oversubscription K stays, maximizing
    slots x oversub.  ``mixed`` (the default) is byte-identical to the
    pre-fleet behaviour.
    """
    from repro.core.integration import pod_plan

    # tier-resident KV budget (DESIGN.md §10): on a pod whose off-core
    # memory is entirely finite, the cache can only grow to the staging
    # bytes left after weight placement.  0 = unbounded (every two-tier
    # default has an unbounded backing tier), so those configs are
    # value-identical to the pre-tier behaviour.
    cap = tier_kv_capacity(cfg, pod, batch=batch, kv_dtype=kv_dtype)
    if cap > 0:
        cache_capacity = min(cache_capacity, cap)

    # oversubscription (DESIGN.md §11): on an all-finite hierarchy the
    # rings left after weight placement beyond the resident batch become
    # virtual slots (K), each swap priced at spill_time of one ring; bytes
    # left after *those* rings fund the prefix-KV store.  Unbounded-backed
    # pods keep K=1 and everything below zero — value-identical to PR 8.
    oversub = tier_kv_oversub(cfg, pod, slots=batch,
                              cache_capacity=cache_capacity,
                              kv_dtype=kv_dtype)
    slot_spill_s = 0.0
    prefix_bytes = 0
    if oversub > 1.0:
        from repro.core.cost_model import AnalyticCostModel

        ring = kv_ring_bytes(cfg, cache_capacity, kv_dtype)
        slot_spill_s = AnalyticCostModel(pod).spill_time(
            ring, 0, pod.backing_tier)
        used = int(round(batch * oversub)) * ring
        prefix_bytes = int(max(0, _tier_bytes_left(cfg, pod) - used))

    if role not in ("mixed", "prefill", "decode"):
        raise ValueError(f"unknown pod role {role!r}; "
                         "known: mixed, prefill, decode")
    knobs = pod_plan(cfg, batch=batch, seq=cache_capacity, phase="decode",
                     num_chips=num_chips, design=design,
                     mode="hybrid" if pipeline else "flat", chip=pod)
    depth = max(knobs.prefetch_depth, 1)
    if pipeline and knobs.microbatch > 0:
        per_interval = max(knobs.microbatch * max(knobs.microbatches, 1), 16)
        chunk = min(per_interval, PREFILL_SAT, cache_capacity)
    else:
        chunk = min(max(16, min(16 * depth, PREFILL_SAT)), cache_capacity)
    if role == "prefill":
        # nothing decodes here, so no interference budget to respect:
        # admit the full saturating pass every tick
        chunk = min(PREFILL_SAT, cache_capacity)
    elif role == "decode":
        # work arrives pre-filled over the fleet tier; keep only the
        # minimal chunk (shed/local work) and the full residency budget
        chunk = min(16, cache_capacity)
    return ServeConfig(batch=batch, cache_capacity=cache_capacity,
                       mode="elk_stream", prefetch_depth=depth,
                       kv_dtype=kv_dtype, prefill_chunk=chunk,
                       steady_interval_s=knobs.interval_s,
                       oversub=oversub, slot_spill_s=slot_spill_s,
                       prefix_cache_bytes=prefix_bytes)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, params: PyTree,
                 scfg: ServeConfig):
        self.cfg = cfg
        self.mesh = mesh
        self.scfg = scfg
        fsdp = scfg.mode == "elk_stream"
        self.p_sh = param_shardings(params, mesh, fsdp=fsdp)
        self.params = jax.device_put(params, self.p_sh)

        self._spec = tfm.CacheSpec(
            capacity=scfg.cache_capacity, batch=scfg.batch,
            kv_dtype=jnp.dtype(scfg.kv_dtype))
        cache_shape = jax.eval_shape(lambda: tfm.init_cache(cfg, self._spec))
        self.c_sh = cache_shardings(cache_shape, mesh)

        bp = batch_axes(mesh)
        self._tok_sh = tok_sh = NamedSharding(mesh, P(bp))
        self._logit_sh = logit_sh = NamedSharding(mesh, P(bp, None, "model"))

        if scfg.mode == "elk_stream":
            from repro.serve.stream import streaming_decode_step

            def decode(params, token, cache):
                return streaming_decode_step(params, cfg, token, cache,
                                             mesh=mesh,
                                             prefetch=scfg.prefetch_depth)
        else:
            def decode(params, token, cache):
                return tfm.decode_step(params, cfg, token, cache)

        # the decode hot loop donates the cache: the compiled step aliases
        # it in-place instead of copying (L,B,Hkv,C,hd) every token
        self._decode = jax.jit(
            decode,
            in_shardings=(self.p_sh, tok_sh, self.c_sh),
            out_shardings=(logit_sh, self.c_sh),
            donate_argnums=(2,),
        )

        def prefill(params, tokens, cache, embeds=None, enc_embeds=None):
            kw = {}
            if embeds is not None:
                kw["embeds"] = embeds
            if enc_embeds is not None:
                kw["enc_embeds"] = enc_embeds
            return tfm.prefill(params, cfg, tokens, cache, **kw)

        self._prefill = jax.jit(prefill, donate_argnums=(2,))

        def prefill_fresh(params, tokens, embeds=None, enc_embeds=None):
            cache = tfm.init_cache(cfg, self._spec)
            return prefill(params, tokens, cache, embeds, enc_embeds)

        self._prefill_fresh = jax.jit(
            prefill_fresh, out_shardings=(logit_sh, self.c_sh))

        # -- continuous-batching state (built lazily by _ensure_slots) ----
        self.slot_cache: Optional[dict] = None
        self._chunk_jits: dict[int, Any] = {}

    # -- public API --------------------------------------------------------
    def prefill(self, tokens: jax.Array, cache: Optional[dict] = None,
                **frontends) -> tuple[jax.Array, dict]:
        """Prefill the prompt.  With ``cache=None`` the initial cache is
        materialized inside the compiled step (nothing to copy); a cache
        passed explicitly is donated — use the returned one."""
        if cache is None:
            return self._prefill_fresh(self.params, tokens,
                                       frontends.get("embeds"),
                                       frontends.get("enc_embeds"))
        return self._prefill(self.params, tokens, cache,
                             frontends.get("embeds"),
                             frontends.get("enc_embeds"))

    def decode(self, token: jax.Array, cache: dict
               ) -> tuple[jax.Array, dict]:
        """One lock-step decode step.  ``cache`` is donated."""
        return self._decode(self.params, token, cache)

    def generate(self, prompts: jax.Array, steps: int,
                 greedy: bool = True) -> jax.Array:
        """prompts: (B, S0) -> (B, S0 + steps) greedy continuation."""
        if steps <= 0:
            return prompts
        fe = frontend_embeddings(self.cfg, prompts.shape[0])
        logits, cache = self.prefill(prompts, **fe)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [prompts, tok[:, None]]
        for _ in range(steps - 1):
            logits, cache = self.decode(tok, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out.append(tok[:, None])
        return jnp.concatenate(out, axis=1)

    # -- slot-batched serving (continuous batching) ------------------------
    def _ensure_slots(self) -> None:
        if self.slot_cache is not None:
            return
        cfg, scfg, mesh = self.cfg, self.scfg, self.mesh
        self._slot_spec = dataclasses.replace(
            self._spec, batch=scfg.slots, per_slot=True)
        self._req_spec = dataclasses.replace(
            self._spec, batch=1, per_slot=True)
        slot_shape = jax.eval_shape(
            lambda: tfm.init_cache(cfg, self._slot_spec))
        self._slot_sh = cache_shardings(slot_shape, mesh)

        if scfg.mode == "elk_stream":
            from repro.serve.stream import streaming_decode_slots

            def decode_slots(params, token, cache):
                return streaming_decode_slots(params, cfg, token, cache,
                                              mesh=mesh,
                                              prefetch=scfg.prefetch_depth)
        else:
            def decode_slots(params, token, cache):
                return tfm.decode_slots(params, cfg, token, cache)

        def step(params, token, cache):
            logits, cache = decode_slots(params, token, cache)
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), \
                cache

        self._step_slots = jax.jit(
            step,
            in_shardings=(self.p_sh, self._tok_sh, self._slot_sh),
            out_shardings=(self._tok_sh, self._slot_sh),
            donate_argnums=(2,),
        )
        self._insert = jax.jit(tfm.cache_insert_slot, donate_argnums=(0,))
        self._evict = jax.jit(tfm.cache_evict_slot, donate_argnums=(0,))
        # extract reads the slot cache before _evict consumes it: no donate
        self._extract = jax.jit(tfm.cache_extract_slot)
        self._req_cache0 = jax.jit(
            lambda: tfm.init_cache(cfg, self._req_spec))
        self.slot_cache = jax.jit(
            lambda: tfm.init_cache(cfg, self._slot_spec),
            out_shardings=self._slot_sh)()

    def new_request_cache(self) -> dict:
        """Fresh single-request per-slot cache for chunked prefill."""
        self._ensure_slots()
        return self._req_cache0()

    def prefill_chunk(self, req_cache: dict, tokens: jax.Array
                      ) -> tuple[jax.Array, dict]:
        """Advance one request's prefill by a chunk of (1, T) tokens.
        Returns (greedy next token (1,), cache).  ``req_cache`` is donated;
        one jit per distinct T (the batcher quantizes chunk lengths to
        powers of two, so the set stays O(log prefill_chunk))."""
        self._ensure_slots()
        t = tokens.shape[1]
        if t not in self._chunk_jits:
            cfg, mesh = self.cfg, self.mesh

            def chunk(params, toks, cache):
                logits, cache = tfm.chunk_prefill(params, cfg, toks, cache,
                                                  mesh=mesh)
                return (jnp.argmax(logits[:, -1, :], axis=-1)
                        .astype(jnp.int32), cache)

            self._chunk_jits[t] = jax.jit(chunk, donate_argnums=(2,))
        return self._chunk_jits[t](self.params, tokens, req_cache)

    def insert_slot(self, slot: int, req_cache: dict) -> None:
        """Splice a prefilled request into ``slot`` of the running batch
        (in place: the slot cache is donated through the insert)."""
        self._ensure_slots()
        self.slot_cache = self._insert(self.slot_cache,
                                       jnp.int32(slot), req_cache)

    def evict_slot(self, slot: int) -> dict:
        """Remove a finished (or preempted) request: reset the slot's
        position and mask its ring tags so the stale K/V is unreachable.
        Returns the evicted per-request state (KV ring + ``pos``/
        ``slot_pos``), which ``insert_slot``/``refill_slot`` round-trips
        bit-identically — callers that only finish a request can drop it."""
        self._ensure_slots()
        state = self._extract(self.slot_cache, jnp.int32(slot))
        self.slot_cache = self._evict(self.slot_cache, jnp.int32(slot))
        return state

    def offload_slot(self, slot: int) -> dict:
        """Spill ``slot`` to the backing tier: evict it and hand back a
        *host-resident copy* of its state (``np.array`` — a real copy, not
        a view, because every engine step donates its cache buffers).  The
        planned cost of this move is ``ServeConfig.slot_spill_s``."""
        return jax.tree.map(lambda a: np.array(a), self.evict_slot(slot))

    def refill_slot(self, slot: int, state: dict) -> None:
        """Refill ``slot`` from an offloaded state (host or device).  Host
        leaves are copied onto fresh device buffers first so a stored state
        (e.g. a prefix-store snapshot) is never aliased into the donated
        slot cache."""
        self._ensure_slots()
        state = jax.tree.map(lambda a: jnp.array(a), state)
        self.slot_cache = self._insert(self.slot_cache,
                                       jnp.int32(slot), state)

    def slot_state_bytes(self) -> int:
        """Bytes one ``offload_slot``/``refill_slot`` moves across the tier
        boundary (one slot's KV ring + metadata)."""
        self._ensure_slots()
        shape = jax.eval_shape(lambda: tfm.init_cache(self.cfg,
                                                      self._req_spec))
        return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(shape)))

    def step(self, tokens: jax.Array) -> jax.Array:
        """One continuous-batching decode step over the mutable slot batch:
        tokens (slots,) int32 -> greedy next token per slot (slots,).  The
        slot cache advances in place (donated buffers, no copy)."""
        self._ensure_slots()
        tok, self.slot_cache = self._step_slots(self.params, tokens,
                                                self.slot_cache)
        return tok
