"""Fleet-tier serving: an SLO-aware router over N pod-backed batchers
(DESIGN.md §12).

One ``ContinuousBatcher`` schedules one pod.  This module is the level
above — the two-level scheduler that turns the single-pod repro into a
serving system:

* a :class:`FleetRouter` owns the shared request queue and dispatches
  across N pods, each a ``ContinuousBatcher`` ticked **in lockstep on a
  common virtual clock**: every pod carries its own clock, the router
  always ticks the laggard, and each tick advances that pod's clock by a
  plan-derived cost (:class:`PodCosts`) — so an N-pod fleet is simulated
  deterministically on one process, and fleet comparisons are scheduling
  deltas, not wall-clock noise;
* pods carry **roles**: a ``prefill`` pod never decodes — its batcher's
  ``handoff`` hook hands every finished prefill (host KV state + first
  token) back to the router, which prices the move over the fleet tier
  (``FleetSpec.migration_time``: offload + inter-pod wire + refill, the
  same ``offload_slot``/``refill_slot`` primitive of DESIGN.md §11
  carried across the inter-pod boundary) and ``adopt``s it into a
  ``decode`` pod once the transfer lands.  ``mixed`` pods do both —  a
  fleet of one mixed pod is value-identical to running the batcher
  directly (:func:`run_virtual_trace`), pinned by test;
* admission is **SLO-aware**: the router predicts TTFT per pod from its
  queue depth, chunk budget, and tick costs (:meth:`FleetRouter.
  predict_ttft` — a deliberate over-estimate: it assumes decode
  interference whenever the pod holds work), routes to the pod
  minimizing it, and with a p99 target set **sheds** requests whose best
  predicted TTFT would violate it — admitted traffic meets the target at
  reduced admitted throughput.

Why disaggregate?  Prefill and decode stress opposite resources: prefill
is a weight-pass over many prompt tokens at once, decode is one token
per resident request per pass.  A mixed pod pays both every tick
(interference) and must keep its chunk budget small; a prefill-role pod
opens the budget to the full saturating pass (``elk_serve_config``
role sizing), so the same prompt costs ~``chunk_ratio`` fewer passes and
none of them carry a decode step.  The migrations that specialization
requires are charged, not free — and re-served by
``chip.simulator.simulate_fleet_traffic`` within 2x of the plan (CI
``fleet-smoke``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.serve.batcher import (Completion, ContinuousBatcher, Request,
                                 _chunk_len, summarize)
from repro.serve.engine import PREFILL_SAT, ServeEngine

ROLES = ("mixed", "prefill", "decode")


class VirtualClock:
    """A callable clock the router advances explicitly.  Batchers built
    on one read simulated seconds, so every timestamp they record
    (arrivals, TTFT, finishes) lives on the fleet's common timeline."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def prefill_passes(length: int, budget: int) -> int:
    """Scheduler ticks one prompt's chunked prefill takes: replays the
    batcher's power-of-two chunking exactly (``_chunk_len``)."""
    n, off = 0, 0
    budget = max(1, budget)
    while off < length:
        off += _chunk_len(length - off, budget)
        n += 1
    return n


@dataclasses.dataclass(frozen=True)
class PodCosts:
    """Virtual-time cost of one scheduler tick on one pod.

    ``decode_step_s`` is one weight pass: the pod's plan-derived steady
    decode interval (every resident slot advances one token).  A prefill
    chunk is priced as weight passes too — ``ceil(tokens /
    prefill_sat)`` of them — which is the chunked-prefill premise ELK's
    gather-ahead window already encodes: below the saturating token
    count a chunk is bandwidth-bound on the same weight traffic a decode
    step moves, so a 16-token chunk and a 128-token chunk cost one pass
    each.  That asymmetry is exactly what role-sized admission budgets
    buy (DESIGN.md §12).  ``tick_overhead_s`` is the fixed per-tick
    dispatch cost; ``spill_s`` prices each charged ring move
    (``ServeConfig.slot_spill_s``).
    """
    decode_step_s: float
    tick_overhead_s: float
    prefill_sat: int = PREFILL_SAT
    spill_s: float = 0.0

    def prefill_cost(self, tokens: int) -> float:
        if tokens <= 0:
            return 0.0
        passes = -(-tokens // max(self.prefill_sat, 1))
        return passes * self.decode_step_s

    def tick_cost(self, *, decoded: bool, prefill_tokens: int,
                  spill_moves: int = 0) -> float:
        return (self.tick_overhead_s
                + (self.decode_step_s if decoded else 0.0)
                + self.prefill_cost(prefill_tokens)
                + spill_moves * self.spill_s)

    @classmethod
    def from_serve_config(cls, scfg, *, default_decode_s: float = 1e-3,
                          overhead_frac: float = 0.5) -> "PodCosts":
        """Plan-derived costs: the hybrid pod plan's steady interval when
        the config carries one, a nominal decode quantum otherwise, with
        the fixed dispatch overhead a fraction of it."""
        d = scfg.steady_interval_s if scfg.steady_interval_s > 0 \
            else default_decode_s
        return cls(decode_step_s=d, tick_overhead_s=overhead_frac * d,
                   spill_s=scfg.slot_spill_s)


@dataclasses.dataclass
class FleetPod:
    """One pod's spec: its engine, role, and (optionally) explicit tick
    costs / batcher knobs.  The router builds the batcher so it can wire
    the virtual clock and the migration hook."""
    engine: ServeEngine
    role: str = "mixed"
    costs: Optional[PodCosts] = None
    oversub: Optional[float] = None
    prefix_store: object = None
    swap_after: int = 4


@dataclasses.dataclass
class _Pod:
    index: int
    role: str
    batcher: ContinuousBatcher
    clock: VirtualClock
    costs: PodCosts


@dataclasses.dataclass
class _Migration:
    req: Request
    state: dict
    generated: list
    first_s: float
    admitted_s: float
    avail_s: float          # when the transfer lands on the target pod
    dst: int


class FleetRouter:
    """Two-level scheduler: the router admits and places requests, each
    pod's ``ContinuousBatcher`` schedules its own slots (DESIGN.md §12).

    ``fleet`` (a ``chip.topology.FleetSpec``) prices prefill->decode
    migrations; without one the wire leg is free and unrecorded (single-
    site fleets, tests).  ``ttft_slo_s`` > 0 arms shedding.
    """

    def __init__(self, pods: Sequence[FleetPod], *, fleet=None,
                 ttft_slo_s: float = 0.0):
        if not pods:
            raise ValueError("FleetRouter needs at least one pod")
        for fp in pods:
            if fp.role not in ROLES:
                raise ValueError(f"unknown pod role {fp.role!r}; "
                                 f"known: {ROLES}")
        if all(fp.role == "decode" for fp in pods):
            raise ValueError("a fleet of only decode pods can never "
                             "prefill; add a prefill or mixed pod")
        if any(fp.role == "prefill" for fp in pods) and \
                not any(fp.role in ("decode", "mixed") for fp in pods):
            raise ValueError("prefill pods need a decode (or mixed) pod "
                             "to migrate to")
        if fleet is not None and fleet.num_pods != len(pods):
            raise ValueError(f"FleetSpec has {fleet.num_pods} pods, "
                             f"router has {len(pods)}")
        self.fleet = fleet
        self.ttft_slo_s = ttft_slo_s
        self.pods: list[_Pod] = []
        self._handoffs: list[tuple] = []
        for i, fp in enumerate(pods):
            clock = VirtualClock()
            handoff = self._make_handoff(i) if fp.role == "prefill" \
                else None
            bat = ContinuousBatcher(
                fp.engine, clock, oversub=fp.oversub,
                prefix_store=fp.prefix_store, swap_after=fp.swap_after,
                handoff=handoff)
            costs = fp.costs or PodCosts.from_serve_config(fp.engine.scfg)
            self.pods.append(_Pod(i, fp.role, bat, clock, costs))
        from collections import deque
        self.queue: deque[Request] = deque()
        self._migrating: list[_Migration] = []
        self.migration_events: list[tuple] = []   # (nbytes, at, src, dst)
        self.planned_migration_s = 0.0
        self.migrations = 0
        self.shed: list[Request] = []
        self.routed = [0] * len(pods)
        self.completed: list[Completion] = []

    # -- migration ---------------------------------------------------------
    def _make_handoff(self, src: int) -> Callable:
        def handoff(req, state, generated, first_s, admitted_s):
            self._handoffs.append((src, req, state, generated, first_s,
                                   admitted_s))
        return handoff

    def _pick_decode_pod(self) -> int:
        """Least-loaded migration target: decode pods first, mixed pods
        as fallback; load = in-flight streams (adoptions in transit
        included) per physical slot."""
        cands = [p for p in self.pods if p.role == "decode"] or \
            [p for p in self.pods if p.role == "mixed"]
        inbound = [0] * len(self.pods)
        for m in self._migrating:
            inbound[m.dst] += 1
        return min(cands, key=lambda p: (
            (len(p.batcher.active) + len(p.batcher.spilled)
             + inbound[p.index]) / max(p.batcher.slots, 1),
            p.clock.t, p.index)).index

    def _drain_handoffs(self) -> None:
        while self._handoffs:
            src, req, state, generated, first_s, admitted_s = \
                self._handoffs.pop(0)
            dst = self._pick_decode_pod()
            nbytes = int(sum(np.asarray(leaf).nbytes
                             for leaf in jax.tree.leaves(state)))
            t = self.pods[src].clock.t
            planned = self.fleet.migration_time(nbytes, src, dst) \
                if self.fleet is not None else 0.0
            self.planned_migration_s += planned
            self.migrations += 1
            if self.fleet is not None:
                self.migration_events.append((nbytes, t, src, dst))
            self._migrating.append(_Migration(
                req=req, state=state, generated=generated,
                first_s=first_s, admitted_s=admitted_s,
                avail_s=t + planned, dst=dst))

    def _deliver_migrations(self) -> None:
        for m in list(self._migrating):
            dp = self.pods[m.dst]
            if m.avail_s <= dp.clock.t + 1e-12:
                dp.batcher.adopt(m.req, m.state, m.generated, m.first_s,
                                 admitted_s=m.admitted_s)
                self._migrating.remove(m)

    # -- SLO-aware routing -------------------------------------------------
    def predict_ttft(self, index: int, prompt_len: int,
                     now: float) -> float:
        """Predicted TTFT of a request routed to pod ``index`` at
        ``now``: the pod's clock lag, plus one prefill pass-cost per
        chunked tick of the work queued ahead of it and of its own
        prompt.  Deliberately conservative: a pass on a mixed pod is
        priced with decode interference whenever the pod holds any work,
        so the prediction upper-bounds the realized TTFT and shedding
        against it keeps admitted p99 under the target."""
        p = self.pods[index]
        bat = p.batcher
        budget = bat.chunk_budget
        passes = prefill_passes(prompt_len, budget)
        ahead = sum(prefill_passes(len(r.prompt), budget)
                    for r in bat.queue)
        if bat.prefilling is not None:
            ahead += prefill_passes(
                len(bat.prefilling.req.prompt) - bat.prefilling.off,
                budget)
        holds_work = bool(bat.active or bat.spilled or bat.queue
                          or bat.prefilling)
        interfere = p.role == "mixed" and (holds_work or ahead > 0)
        pass_cost = p.costs.tick_cost(decoded=interfere,
                                      prefill_tokens=budget)
        return max(p.clock.t - now, 0.0) + (ahead + passes) * pass_cost

    def _route(self, now: float) -> None:
        while self.queue:
            req = self.queue.popleft()
            best, best_t = -1, float("inf")
            for p in self.pods:
                if p.role == "decode":
                    continue
                t = self.predict_ttft(p.index, len(req.prompt), now)
                if t < best_t - 1e-12:
                    best, best_t = p.index, t
            if self.ttft_slo_s > 0 and best_t > self.ttft_slo_s:
                self.shed.append(req)
                continue
            self.routed[best] += 1
            self.pods[best].batcher.submit(req)

    # -- the lockstep loop -------------------------------------------------
    @property
    def wall_s(self) -> float:
        return max(p.clock.t for p in self.pods)

    def run(self, requests: list[Request]) -> list[Completion]:
        """Replay an arrival trace across the fleet to completion.
        Returns the merged completions in global finish order (shed
        requests never complete; see ``self.shed``)."""
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        while pending or self.queue or self._migrating or \
                any(p.batcher.busy for p in self.pods):
            busy = [p for p in self.pods if p.batcher.busy]
            if busy:
                now = min(p.clock.t for p in busy)
            else:
                # fleet idle: jump to the next event on the timeline
                events = [m.avail_s for m in self._migrating]
                if pending:
                    events.append(pending[0].arrival_s)
                now = max(self.wall_s, min(events)) if events \
                    else self.wall_s
            for p in self.pods:       # idle pods ride the common clock
                if not p.batcher.busy and p.clock.t < now:
                    p.clock.t = now
            while pending and pending[0].arrival_s <= now + 1e-12:
                self.queue.append(pending.pop(0))
            self._route(now)
            self._deliver_migrations()
            busy = [p for p in self.pods if p.batcher.busy]
            if not busy:
                continue
            p = min(busy, key=lambda q: (q.clock.t, q.index))
            spills0 = len(p.batcher.spill_events)
            p.batcher.tick()
            p.clock.advance(p.costs.tick_cost(
                decoded=p.batcher.tick_decoded,
                prefill_tokens=p.batcher.tick_prefill_tokens,
                spill_moves=len(p.batcher.spill_events) - spills0))
            self._drain_handoffs()
        out = sorted((c for p in self.pods for c in p.batcher.completed),
                     key=lambda c: c.finish_s)
        for i, c in enumerate(out):
            c.finish_order = i
        self.completed = out
        return out

    def summary(self) -> dict:
        """Merged ``summarize`` over the fleet's virtual timeline plus
        the router-level signals (migrations, shedding, placement)."""
        stats = summarize(self.completed, self.wall_s) if self.completed \
            else {"requests": 0, "wall_s": round(self.wall_s, 4)}
        stats["pods"] = len(self.pods)
        stats["roles"] = [p.role for p in self.pods]
        stats["routed"] = list(self.routed)
        stats["migrations"] = self.migrations
        stats["planned_migration_s"] = round(self.planned_migration_s, 6)
        stats["shed"] = len(self.shed)
        return stats


def run_virtual_trace(batcher: ContinuousBatcher, requests: list[Request],
                      costs: PodCosts) -> list[Completion]:
    """Drive one ``ContinuousBatcher`` on the fleet's virtual clock — the
    single-pod reference a degenerate one-mixed-pod fleet must reproduce
    value-identically (same completions, same summary).  The batcher must
    have been built with a :class:`VirtualClock`."""
    clock = batcher.clock
    if not isinstance(clock, VirtualClock):
        raise TypeError("run_virtual_trace needs a batcher built on a "
                        "VirtualClock (ContinuousBatcher(eng, clock))")
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    batcher.t0 = clock()
    while pending or batcher.busy:
        now = clock() - batcher.t0
        if not batcher.busy and pending and pending[0].arrival_s > now:
            clock.t = batcher.t0 + pending[0].arrival_s
            now = pending[0].arrival_s
        while pending and pending[0].arrival_s <= now + 1e-12:
            batcher.submit(pending.pop(0))
        spills0 = len(batcher.spill_events)
        batcher.tick()
        clock.advance(costs.tick_cost(
            decoded=batcher.tick_decoded,
            prefill_tokens=batcher.tick_prefill_tokens,
            spill_moves=len(batcher.spill_events) - spills0))
    return batcher.completed


def predict_fleet_rates(costs: PodCosts, *, num_pods: int, n_prefill: int,
                        slots: int, prompt_len: int,
                        chunk_mixed: int = 16,
                        chunk_prefill: int = PREFILL_SAT) -> dict:
    """Closed-form rate model of the disaggregation trade (used by
    ``chip.dse.fleet_sweep`` and as the router's intuition, not a
    simulator): steady generated-token rate and one prompt's prefill
    latency for ``num_pods`` mixed replicas vs an
    ``n_prefill``/``num_pods - n_prefill`` prefill/decode split, under
    the :class:`PodCosts` tick pricing."""
    if not 0 < n_prefill < num_pods:
        raise ValueError(f"need 0 < n_prefill < num_pods, got "
                         f"{n_prefill}/{num_pods}")
    o, d = costs.tick_overhead_s, costs.decode_step_s
    mixed_tick = o + d + costs.prefill_cost(chunk_mixed)
    mixed_passes = prefill_passes(prompt_len, chunk_mixed)
    pf_tick = o + costs.prefill_cost(chunk_prefill)
    pf_passes = prefill_passes(prompt_len, chunk_prefill)
    dec_tick = o + d
    n_dec = num_pods - n_prefill
    return {
        "mixed_gen_tok_s": num_pods * slots / mixed_tick,
        "mixed_prefill_s": mixed_passes * mixed_tick,
        "mixed_prefill_req_s": num_pods / (mixed_passes * mixed_tick),
        "disagg_gen_tok_s": n_dec * slots / dec_tick,
        "disagg_prefill_s": pf_passes * pf_tick,
        "disagg_prefill_req_s": n_prefill / (pf_passes * pf_tick),
    }
