"""Content-hash prefix KV store (DESIGN.md §11).

A fleet serving chat traffic re-prefills the same system prompt thousands
of times.  The batcher snapshots a request's per-slot cache at chunk
boundaries during prefill (a *strict* prefix of the prompt, within ring
capacity so nothing has wrapped) and keys it by the token content.  A
later request whose prompt starts with the same tokens skips that prefix:
admission becomes a ``refill_slot``-priced restore of the snapshot plus a
chunked prefill of the tail — and because any chunking of an in-capacity
prompt is bit-identical to a single pass (``tests/test_serve_batcher``),
the cached-prefix greedy continuation equals the cold path exactly.

Snapshots are **host-resident real copies** (``np.array``): every engine
step donates its cache buffers, so a view would dangle.  Restores copy
back onto fresh device buffers (``ServeEngine.refill_slot`` /
``jnp.array``), so a stored state is never consumed.  One store serves one
(model, cache capacity, kv dtype) family — leaf shapes must match the
engine's per-request spec.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional

import jax
import numpy as np


def prefix_key(tokens) -> bytes:
    """Content hash of a token prefix.  int32-widened bytes make the key
    unambiguous in both values and length."""
    a = np.ascontiguousarray(np.asarray(tokens, np.int32))
    return hashlib.sha1(a.tobytes()).digest()


def state_bytes(state: dict) -> int:
    return int(sum(int(np.prod(np.shape(leaf))) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(state)))


class PrefixStore:
    """LRU byte-budgeted map: token-prefix hash -> host KV snapshot.

    ``put`` stores a snapshot taken at prefix length k (``state["pos"]``
    must equal k); ``lookup`` returns the longest stored prefix of a
    prompt.  Both run over the set of *distinct stored lengths*, so lookup
    hashes O(#lengths) prefixes, not O(prompt)."""

    def __init__(self, capacity_bytes: int = 64 << 20):
        self.capacity_bytes = int(capacity_bytes)
        self._entries: OrderedDict[bytes, tuple[int, int, dict]] = \
            OrderedDict()          # key -> (prefix_len, nbytes, state)
        self.bytes = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, tokens, state: dict) -> bool:
        """Store a snapshot of ``tokens`` (the prefix itself, not the full
        prompt).  Returns False when it was already stored or cannot fit."""
        nbytes = state_bytes(state)
        if nbytes > self.capacity_bytes:
            return False
        key = prefix_key(tokens)
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        while self._entries and self.bytes + nbytes > self.capacity_bytes:
            _, (_, old_nb, _) = self._entries.popitem(last=False)
            self.bytes -= old_nb
        self._entries[key] = (len(np.asarray(tokens)), nbytes, state)
        self.bytes += nbytes
        return True

    def lookup(self, prompt, max_len: int) -> Optional[tuple[int, dict]]:
        """Longest stored strict prefix of ``prompt`` with length <=
        ``max_len`` (callers pass ``min(len(prompt) - 1, capacity)`` so the
        tail chunk still produces last-token logits and the snapshot never
        saw a wrapped ring).  Returns (prefix_len, host_state) or None."""
        prompt = np.asarray(prompt, np.int32)
        lens = sorted({ln for ln, _, _ in self._entries.values()},
                      reverse=True)
        for k in lens:
            if k > max_len or k > len(prompt):
                continue
            entry = self._entries.get(prefix_key(prompt[:k]))
            if entry is not None:
                self._entries.move_to_end(prefix_key(prompt[:k]))
                self.hits += 1
                return k, entry[2]
        self.misses += 1
        return None
