"""ELK weight-streaming decoder — the paper's technique at pod level.

Mapping (DESIGN.md §3A): layer weights live *sharded over the data axis*
(preload state, each device holds 1/k); before a layer executes its weights
are all-gathered to replicated-over-data (execute state).  The gather of
block ``i + p`` is issued while block ``i`` computes — ``p`` is the paper's
*preload number*, chosen by the ELK scheduler
(``core/integration.pod_plan``), and the rolling window of ``p`` gathered
blocks is the *preload space* (the on-chip memory capacity contention ① is
now an HBM capacity contention; the ICI contention between these gathers
and TP collectives is contention ②).

Mechanically: a ``lax.scan`` whose carry holds the ``p`` gathered blocks;
``with_sharding_constraint`` forces the preload->execute transition, and
XLA's latency-hiding scheduler overlaps the gather with the previous
block's compute because they have no data dependency.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import _path_str, param_pspec
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

PyTree = Any


def _drop_axis(spec: P, axis: str) -> P:
    return P(*[(None if ax == axis else ax) for ax in spec])


def execute_state_shardings(params_blocks, mesh: Mesh) -> PyTree:
    """Sharding of one *gathered* block (preload state minus the data axis
    and the stacked leading dim)."""
    def one(path, leaf):
        spec = param_pspec("blocks/" + _path_str(path), jnp.shape(leaf),
                           mesh, fsdp=True)
        spec = _drop_axis(spec, "data")
        return NamedSharding(mesh, P(*spec[1:]))   # drop stacked dim
    return jax.tree_util.tree_map_with_path(one, params_blocks)


def _index_block(params_blocks, i):
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        params_blocks)


def _gather(params_blocks, i, exec_shardings):
    blk = _index_block(params_blocks, i)
    return jax.tree.map(lax.with_sharding_constraint, blk, exec_shardings)


def streaming_decoder(params, cfg: ModelConfig, x, ctx, cache,
                      mesh: Mesh, prefetch: int = 2):
    """Drop-in replacement for ``transformer._run_decoder`` that streams
    block weights with an ELK gather-ahead window of depth ``prefetch``.

    Returns (x, new_cache_layers) with the same contract."""
    prefix, period, n_blocks = tfm.block_structure(cfg)
    kinds = tfm.layer_kinds(cfg)
    spec = tfm.attn_spec(cfg)
    new_layers: list[Optional[dict]] = [None] * cfg.num_layers

    for li in range(prefix):
        lc = _cache_slice(cache, li)
        x, nc = tfm._decoder_layer(x, params["prefix"][li], cfg, kinds[li],
                                   spec, ctx, lc)
        new_layers[li] = nc
    if not n_blocks:
        return x, new_layers

    pblocks = tuple(params["blocks"])
    exec_sh = execute_state_shardings(pblocks, mesh)
    p = max(1, min(prefetch, n_blocks))

    # preload window: blocks 0..p-1 gathered up front (the paper's initial
    # pipeline fill)
    window = [_gather(pblocks, jnp.int32(i), exec_sh) for i in range(p)]
    window = jax.tree.map(lambda *xs: jnp.stack(xs), *window)

    xs_cache = _stack_cache(cache, cfg) if cache is not None else None

    def body(carry, step_xs):
        x, win = carry
        i, bcache = step_xs
        cur = jax.tree.map(lambda a: a[0], win)
        outs = []
        for slot in range(period):
            kind = kinds[prefix + slot]
            lc = (jax.tree.map(lambda a: a[slot], bcache)
                  if bcache is not None else None)
            x, nc = tfm._decoder_layer(x, cur[slot], cfg, kind, spec,
                                       ctx, lc)
            outs.append(nc)
        # issue the gather of block i+p (clamped; tail gathers are no-ops
        # on already-resident data)
        nxt = _gather(pblocks, jnp.minimum(i + p, n_blocks - 1), exec_sh)
        win = jax.tree.map(
            lambda a, n: jnp.concatenate([a[1:], n[None]], axis=0),
            win, nxt)
        ys = (jax.tree.map(lambda *zs: jnp.stack(zs), *outs)
              if outs[0] else None)
        return (x, win), ys

    idxs = jnp.arange(n_blocks, dtype=jnp.int32)
    (x, _), ys = lax.scan(body, (x, window), (idxs, xs_cache))
    if ys is not None:
        flat = jax.tree.map(
            lambda a: a.reshape((n_blocks * period,) + a.shape[2:]), ys)
        for off in range(n_blocks * period):
            new_layers[prefix + off] = jax.tree.map(lambda a: a[off], flat)
    return x, new_layers


def _cache_slice(cache, li):
    if cache is None:
        return None
    out = {}
    for key in ("k", "v", "rwkv_state", "ssm_state"):
        if key in cache:
            out[key] = cache[key][li]
    if "k_scale" in cache:
        out["scales"] = (cache["k_scale"][li], cache["v_scale"][li])
    return out


def _stack_cache(cache, cfg: ModelConfig):
    prefix, period, n_blocks = tfm.block_structure(cfg)

    def stack(arr):
        body = arr[prefix:prefix + n_blocks * period]
        return body.reshape((n_blocks, period) + arr.shape[1:])

    out = {}
    for key in ("k", "v", "rwkv_state", "ssm_state"):
        if key in cache:
            out[key] = stack(cache[key])
    if "k_scale" in cache:
        out["scales"] = (stack(cache["k_scale"]), stack(cache["v_scale"]))
    return out


def streaming_decode_slots(params, cfg: ModelConfig, token, cache,
                           mesh: Mesh, prefetch: int = 2):
    """ELK-streaming version of ``transformer.decode_slots``: one
    continuous-batching step over a per-slot cache, with block weights
    gathered ahead through the same preload window as the lock-step path.
    """
    if cfg.encoder_layers:
        raise ValueError("decode_slots does not support enc-dec models")
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache["pos"]                                  # (B,)
    ctx, slot_pos = tfm._slots_ctx(cache, pos[:, None], mesh)
    x, new_layers = streaming_decoder(params, cfg, x, ctx, cache, mesh,
                                      prefetch)
    new_cache = tfm._merge_cache(cfg, cache, new_layers, pos + 1, slot_pos)
    return tfm._logits(params, cfg, x), new_cache


def streaming_decode_step(params, cfg: ModelConfig, token, cache,
                          mesh: Mesh, prefetch: int = 2):
    """ELK-streaming version of ``transformer.decode_step``.

    Enc-dec models fall back to the plain decode path: their decoders are
    tiny (whisper-tiny: 37M) and cross-attention K/V lives in the cache —
    nothing worth streaming."""
    if cfg.encoder_layers:
        return tfm.decode_step(params, cfg, token, cache)
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache["pos"]
    slot_pos = None
    if "slot_pos" in cache:
        c = cache["slot_pos"].shape[0]
        slot_pos = cache["slot_pos"].at[pos % c].set(pos)
    ctx = {"mode": "decode", "pos": pos, "slot_pos": slot_pos,
           "enc_out": None, "mesh": mesh}
    x, new_layers = streaming_decoder(params, cfg, x, ctx, cache, mesh,
                                      prefetch)
    new_cache = tfm._merge_cache(cfg, cache, new_layers, pos + 1, slot_pos)
    return tfm._logits(params, cfg, x), new_cache
