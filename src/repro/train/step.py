"""The jit'd training step: microbatched grad accumulation + AdamW.

Structure (per the large-scale runnability requirements):

* **microbatching** — the global batch is split into ``grad_accum``
  microbatches processed under ``lax.scan``; only one microbatch's
  activations/logits are ever live (the full-batch logits of a 150k-vocab
  model would be TBs).
* **sharding** — params per ``distributed.sharding`` rules (TP/EP; FSDP
  optional), batch over (pod, data), optimizer state inherits param
  placement (ZeRO-1 via the FSDP rule).
* **gradient compression** — cross-pod traffic optionally bf16 or
  error-feedback int8 (``distributed.compression``); the error-feedback
  buffer threads through the step signature.
* **overlap** — gradients are computed per-microbatch and accumulated;
  XLA's latency-hiding scheduler overlaps the reduce of microbatch ``i``
  with the backward of ``i+1`` (the scan body keeps them independent).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression as comp
from repro.distributed.sharding import (batch_shardings, constrain_batch,
                                        param_shardings, replicated)
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw

PyTree = Any


def make_loss_fn(cfg: ModelConfig, mesh, layout: str = "tp"):
    def loss_fn(params, batch):
        batch = {k: (constrain_batch(v, mesh) if hasattr(v, "ndim")
                     and v.ndim >= 1 else v) for k, v in batch.items()}
        return tfm.loss_fn(params, cfg, batch, remat=True, mesh=mesh,
                           layout=layout)
    return loss_fn


def make_train_step(cfg: ModelConfig, mesh, opt_cfg: adamw.AdamWConfig,
                    grad_accum: int = 1, compression: str = "none",
                    fsdp: bool = False, accum_dtype=None,
                    zero_shardings=None, param_out_shardings=None,
                    layout: str = "tp"):
    """Returns (step_fn, shardings) where step_fn(params, opt_state, batch,
    err_fb) -> (params, opt_state, metrics, err_fb) is ready for jit.

    ZeRO-1 structure: gradients are accumulated and the AdamW update runs
    entirely in the *ZeRO domain* (``zero_shardings`` — params sharded over
    data and model), where moments live; the updated params are gathered
    back to their live placement (``param_out_shardings``) once per step.
    Mixing placements inside the update would make XLA reshard the full
    fp32 moments instead.

    ``accum_dtype`` sets the gradient-accumulation buffer dtype: fp32 by
    default; bf16 halves the largest transient for trillion-param models
    (kimi-k2) — with 8-16 microbatches the bf16 accumulation error is well
    under Adam's own epsilon floor."""
    loss_fn = make_loss_fn(cfg, mesh, layout)
    acc_dt = accum_dtype or (jnp.bfloat16 if cfg.param_count() > 1e11
                             else jnp.float32)

    def to_zero(tree):
        if zero_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            zero_shardings)

    def to_live(tree):
        if param_out_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_out_shardings)

    def split_micro(batch):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import batch_axes
        bp = batch_axes(mesh)

        def f(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            out = x.reshape((grad_accum, b // grad_accum) + x.shape[1:])
            # keep the batch sharding on the *microbatch* dim — GSPMD would
            # otherwise move it to the scan dim, replicating every
            # microbatch across the data axis (16x live activations)
            return jax.lax.with_sharding_constraint(
                out, NamedSharding(
                    mesh, P(None, bp, *([None] * (out.ndim - 2)))))
        return jax.tree.map(f, batch)

    def train_step(params, opt_state, batch, err_fb):
        if grad_accum > 1:
            micro = split_micro(batch)

            def accum(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                # accumulate in the ZeRO domain: the add's output sharding
                # makes XLA keep only the local grad shard per microbatch
                gsum = to_zero(jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g))
                return (gsum, lsum + l), None

            zeros = to_zero(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = to_zero(grads)

        grads, err_fb = comp.compress_grads(grads, err_fb, compression)
        new_p, opt_state, metrics = adamw.apply_update(
            to_zero(params), grads, opt_state, opt_cfg)
        params = to_live(new_p)      # one all-gather per step
        metrics["loss"] = loss
        return params, opt_state, metrics, err_fb

    return train_step


def jit_train_step(cfg: ModelConfig, mesh, opt_cfg: adamw.AdamWConfig,
                   params_like: PyTree, batch_like: PyTree,
                   grad_accum: int = 1, compression: str = "none",
                   fsdp: bool = False, layout: str = "tp"):
    """Build the fully-specified jit: in/out shardings pinned so the dry-run
    and the trainer share one lowering path.

    ``layout='fsdp2d'`` holds params fully sharded over (data x model) —
    they are already in the ZeRO domain, so to_zero/to_live are no-ops and
    the only per-step weight traffic is the per-layer forward/backward
    gathers (constant in batch size)."""
    p_sh = param_shardings(params_like, mesh, fsdp=fsdp, layout=layout)
    zero_sh = (p_sh if layout == "fsdp2d"
               else param_shardings(params_like, mesh, fsdp=True))
    # eval_shape: params_like may be ShapeDtypeStructs (the dry-run path)
    opt_state_like = jax.eval_shape(
        functools.partial(adamw.init_state, cfg=opt_cfg), params_like)
    s_sh = (adamw.state_shardings(opt_state_like, p_sh, mesh,
                                  params=params_like)
            if layout != "fsdp2d" else
            {"step": replicated(mesh), "m": p_sh, "v": p_sh})
    b_sh = batch_shardings(batch_like, mesh)
    # error-feedback buffer mirrors the ZeRO placement (param-shaped)
    e_sh = zero_sh if compression == "int8" else None

    step = make_train_step(cfg, mesh, opt_cfg, grad_accum, compression,
                           fsdp, zero_shardings=zero_sh,
                           param_out_shardings=p_sh, layout=layout)
    metrics_sh = {"loss": replicated(mesh), "grad_norm": replicated(mesh),
                  "lr": replicated(mesh)}
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, s_sh, b_sh, e_sh),
        out_shardings=(p_sh, s_sh, metrics_sh, e_sh),
        donate_argnums=(0, 1),
    )
    return jitted, {"params": p_sh, "opt": s_sh, "batch": b_sh, "err": e_sh}
