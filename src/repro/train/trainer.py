"""Fault-tolerant training loop.

Production contract for thousand-node fleets:

* **checkpoint/restart** — async atomic checkpoints every
  ``ckpt_every`` steps; on construction the trainer restores the latest
  checkpoint if one exists (a restarted job resumes transparently; the
  deterministic data pipeline replays from the step counter).
* **heartbeat** — a per-step heartbeat file (step + walltime); an external
  supervisor (or the pod scheduler) detects dead workers by staleness.
* **straggler mitigation** — per-step deadline tracking: steps slower than
  ``straggler_factor`` x the rolling median are logged and counted; after
  ``straggler_patience`` consecutive slow steps the trainer invokes
  ``on_straggler`` (default: checkpoint immediately so the scheduler can
  reslice the job — on real fleets this is where you'd trigger hot-spare
  swap-in).
* **failure injection** — ``failure_hook(step)`` raising mid-run is the
  crash; tests assert a fresh Trainer resumes losslessly.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from statistics import median
from typing import Callable, Optional

import jax

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, make_dataset
from repro.distributed import compression as comp
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.step import jit_train_step


@dataclasses.dataclass
class TrainerConfig:
    workdir: str
    total_steps: int = 100
    ckpt_every: int = 20
    keep_ckpts: int = 3
    grad_accum: int = 1
    compression: str = "none"
    fsdp: bool = False
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    heartbeat_file: str = "heartbeat.json"
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 opt_cfg: adamw.AdamWConfig, tcfg: TrainerConfig, mesh,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 on_straggler: Optional[Callable[["Trainer"], None]] = None):
        self.cfg = model_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg
        self.data = make_dataset(data_cfg, model_cfg)
        self.store = CheckpointStore(os.path.join(tcfg.workdir, "ckpt"),
                                     keep=tcfg.keep_ckpts)
        self.failure_hook = failure_hook
        self.on_straggler = on_straggler or (lambda t: t.checkpoint())
        self.step_times: list[float] = []
        self.straggler_strikes = 0
        self.straggler_events = 0
        self.metrics_log: list[dict] = []

        params = tfm.init_params(jax.random.PRNGKey(tcfg.seed), model_cfg)
        opt_state = adamw.init_state(params, opt_cfg)
        batch0 = self.data.batch_at(0)
        self.step_fn, self.shardings = jit_train_step(
            model_cfg, mesh, opt_cfg, params, batch0,
            grad_accum=tcfg.grad_accum, compression=tcfg.compression,
            fsdp=tcfg.fsdp)
        self.err_fb = comp.init_error_feedback(params, tcfg.compression)

        # restore-or-init (elastic: shardings belong to *this* mesh, the
        # checkpoint may have been written on another)
        latest = self.store.latest_step()
        if latest is not None:
            state = {"params": params, "opt": opt_state}
            state = self.store.restore(
                latest, state, {"params": self.shardings["params"],
                                "opt": self.shardings["opt"]})
            params, opt_state = state["params"], state["opt"]
            self.step = latest
        else:
            params = jax.device_put(params, self.shardings["params"])
            opt_state = jax.device_put(opt_state, self.shardings["opt"])
            self.step = 0
        self.params, self.opt_state = params, opt_state

    # -- fault-tolerance plumbing -----------------------------------------
    def _heartbeat(self, step: int, step_time: float) -> None:
        path = os.path.join(self.tcfg.workdir, self.tcfg.heartbeat_file)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "step_time": step_time}, f)
        os.replace(tmp, path)

    def _check_straggler(self, dt: float) -> None:
        self.step_times.append(dt)
        window = self.step_times[-32:]
        if len(window) < 5:
            return
        med = median(window[:-1])
        if dt > self.tcfg.straggler_factor * med:
            self.straggler_strikes += 1
            if self.straggler_strikes >= self.tcfg.straggler_patience:
                self.straggler_events += 1
                self.straggler_strikes = 0
                self.on_straggler(self)
        else:
            self.straggler_strikes = 0

    def checkpoint(self) -> None:
        self.store.save_async(self.step, {"params": self.params,
                                          "opt": self.opt_state})

    # -- main loop ----------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> list[dict]:
        end = self.step + steps if steps is not None else self.tcfg.total_steps
        while self.step < end:
            if self.failure_hook is not None:
                self.failure_hook(self.step)
            batch = self.data.batch_at(self.step)
            t0 = time.perf_counter()
            out = self.step_fn(self.params, self.opt_state, batch,
                               self.err_fb)
            self.params, self.opt_state, metrics, self.err_fb = out
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = self.step
            m["step_time"] = dt
            self.metrics_log.append(m)
            self._heartbeat(self.step, dt)
            self._check_straggler(dt)
            if self.step % self.tcfg.ckpt_every == 0:
                self.checkpoint()
        self.store.wait()
        return self.metrics_log
