"""Shared fixtures.  NOTE: never import repro.launch.dryrun here — it
forces 512 host devices; smoke tests must see the real (1-device) CPU."""
import jax
import pytest


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
