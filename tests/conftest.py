"""Shared fixtures.  NOTE: never import repro.launch.dryrun here — it
forces 512 host devices; smoke tests must see the real (1-device) CPU."""
import jax
import pytest

from repro.launch.mesh import _make_mesh


@pytest.fixture(scope="session")
def mesh11():
    return _make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
