"""Unit + property tests for the faithful ELK compiler core (§4.2-§4.4)."""

import math

import pytest

pytest.importorskip("hypothesis",
                    reason="dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.chip.config import ipu_pod4_hbm, ipu_mk2
from repro.configs import get_config
from repro.core.allocator import WindowItem, allocate
from repro.core.baselines import DESIGNS, build_plan, ideal_plan
from repro.core.elk import compare_designs, compile_model
from repro.core.graph import build_graph
from repro.core.partition import (enumerate_exec_plans,
                                  enumerate_preload_plans)
from repro.core.reorder import (apply_heavy_order, heavy_ops_in_layer,
                                valid_heavy_orders)
from repro.core.scheduler import Scheduler

CHIP = ipu_pod4_hbm()
CFG = get_config("llama2_13b")
GRAPH = build_graph(CFG, batch=32, seq=2048, phase="decode")


# ---------------------------------------------------------------------------
# partition plans
# ---------------------------------------------------------------------------

class TestPartitionPlans:
    def test_exec_plans_pareto(self):
        """Plans sorted max-space first; times strictly increase as space
        decreases (Pareto frontier, §4.3)."""
        op = next(o for o in GRAPH.ops if o.kind == "matmul")
        plans = enumerate_exec_plans(op, CHIP)
        assert plans, "no feasible plan"
        for a, b in zip(plans, plans[1:]):
            assert a.space >= b.space
            assert a.time <= b.time + 1e-12

    def test_exec_plans_fit_sram(self):
        op = next(o for o in GRAPH.ops if o.kind == "matmul")
        for p in enumerate_exec_plans(op, CHIP):
            assert p.space <= CHIP.usable_sram_per_core
            assert p.cores_used <= CHIP.num_cores

    def test_preload_plans_pareto(self):
        op = max(GRAPH.ops, key=lambda o: o.hbm_bytes)
        ep = enumerate_exec_plans(op, CHIP)[0]
        pps = enumerate_preload_plans(op, ep, CHIP)
        assert pps
        for a, b in zip(pps, pps[1:]):
            assert a.space >= b.space
            assert a.dist_time <= b.dist_time + 1e-12
        # frac=1 broadcasts everything: zero distribution time
        assert pps[0].frac == 1.0
        assert pps[0].dist_time == 0.0

    def test_preload_hbm_bytes_invariant(self):
        """HBM read volume is plan-independent (§3.3 trades NoC, not HBM)."""
        op = max(GRAPH.ops, key=lambda o: o.hbm_bytes)
        ep = enumerate_exec_plans(op, CHIP)[0]
        pps = enumerate_preload_plans(op, ep, CHIP)
        assert len({p.hbm_bytes for p in pps}) == 1


# ---------------------------------------------------------------------------
# allocator (§4.3)
# ---------------------------------------------------------------------------

class TestAllocator:
    def _items(self, k=3):
        ops = [o for o in GRAPH.ops if o.kind == "matmul"][:k + 1]
        items = [WindowItem(0, "exec", enumerate_exec_plans(ops[0], CHIP))]
        for i, op in enumerate(ops[1:], start=1):
            ep = enumerate_exec_plans(op, CHIP)[0]
            items.append(WindowItem(i, "preload",
                                    enumerate_preload_plans(op, ep, CHIP)))
        return items

    def test_allocation_fits(self):
        items = self._items()
        alloc = allocate(CHIP, items)
        assert alloc.feasible
        assert alloc.space <= CHIP.usable_sram_per_core

    def test_monotone_in_capacity(self):
        """Shrinking capacity never improves the window cost."""
        items = self._items()
        cap = CHIP.usable_sram_per_core
        costs = []
        for frac in (1.0, 0.5, 0.25):
            a = allocate(CHIP, items, capacity=int(cap * frac))
            if a.feasible:
                costs.append(a.cost)
        assert costs == sorted(costs)

    @given(frac=st.floats(0.05, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_allocation_never_overflows(self, frac):
        items = self._items(2)
        cap = int(CHIP.usable_sram_per_core * frac)
        a = allocate(CHIP, items, capacity=cap)
        if a.feasible:
            assert a.space <= cap


# ---------------------------------------------------------------------------
# scheduler (§4.2)
# ---------------------------------------------------------------------------

class TestScheduler:
    @pytest.fixture(scope="class")
    def small_graph(self):
        import dataclasses
        cfg = dataclasses.replace(CFG, num_layers=2)
        return build_graph(cfg, batch=32, seq=2048, phase="decode")

    def test_schedule_consistency(self, small_graph):
        plan = Scheduler(small_graph, CHIP).schedule()
        n = len(small_graph.ops)
        for i in range(n):
            t = plan.timing[i]
            # preload completes before execution starts
            assert t.t_e_pre <= t.t_s_exe + 1e-9
            assert t.t_s_exe <= t.t_e_exe
        # execution is sequential in graph order
        for i in range(n - 1):
            assert plan.timing[i].t_e_exe <= plan.timing[i + 1].t_s_exe + 1e-9

    def test_preloads_sequential(self, small_graph):
        """§4.5 rule 2: preloads never overlap each other."""
        plan = Scheduler(small_graph, CHIP).schedule()
        spans = sorted((plan.timing[j].t_s_pre, plan.timing[j].t_e_pre)
                       for j in range(len(small_graph.ops)))
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert e1 <= s2 + 1e-9

    def test_moe_preload_dep(self):
        """§7: expert preloads wait for the router's execution."""
        cfg = get_config("kimi_k2_1t_a32b")
        import dataclasses
        cfg = dataclasses.replace(cfg, num_layers=2)
        g = build_graph(cfg, batch=8, seq=128, phase="decode")
        dep_ops = [(i, op) for i, op in enumerate(g.ops)
                   if op.preload_dep >= 0]
        assert dep_ops, "MoE graph must contain router-dependent preloads"
        plan = Scheduler(g, CHIP).schedule()
        for i, op in dep_ops:
            assert plan.timing[i].t_s_pre >= \
                plan.timing[op.preload_dep].t_e_exe - 1e-9

    def test_more_preload_never_hurts(self, small_graph):
        """max_preload=0-ish vs deep preload: deeper never slower (the
        scheduler may always choose shallower)."""
        shallow = Scheduler(small_graph, CHIP, max_preload=1).schedule()
        deep = Scheduler(small_graph, CHIP, max_preload=32).schedule()
        assert deep.total_time <= shallow.total_time * 1.001


# ---------------------------------------------------------------------------
# reorder (§4.4)
# ---------------------------------------------------------------------------

class TestReorder:
    def test_orders_are_permutations(self):
        heavy = heavy_ops_in_layer(GRAPH)
        for order in valid_heavy_orders(GRAPH, CHIP, max_orders=16):
            assert sorted(order) == sorted(heavy)

    def test_apply_heavy_order_permutation(self):
        heavy = heavy_ops_in_layer(GRAPH)
        orders = list(valid_heavy_orders(GRAPH, CHIP, max_orders=4))
        for horder in orders:
            pi = apply_heavy_order(GRAPH, horder)
            assert sorted(pi) == list(range(len(GRAPH.ops)))

    def test_identity_order_included(self):
        heavy = tuple(heavy_ops_in_layer(GRAPH))
        orders = list(valid_heavy_orders(GRAPH, CHIP, max_orders=720))
        assert heavy in orders


# ---------------------------------------------------------------------------
# end-to-end designs (§6.1/§6.2)
# ---------------------------------------------------------------------------

class TestDesigns:
    @pytest.fixture(scope="class")
    def plans(self):
        return compare_designs(CFG, CHIP, batch=32, seq=2048,
                               phase="decode")

    def test_all_designs_build(self, plans):
        assert set(plans) == set(DESIGNS)
        for p in plans.values():
            assert p.total_time > 0
            assert math.isfinite(p.total_time)

    def test_paper_ordering(self, plans):
        """Basic >= Static >= ELK-Dyn >= ELK-Full >= Ideal (total time)."""
        assert plans["Basic"].total_time >= plans["Static"].total_time * 0.999
        assert plans["Static"].total_time >= \
            plans["ELK-Dyn"].total_time * 0.999
        assert plans["ELK-Dyn"].total_time >= \
            plans["ELK-Full"].total_time * 0.999
        assert plans["ELK-Full"].total_time >= \
            plans["Ideal"].total_time * 0.999

    def test_elk_full_near_ideal(self, plans):
        """Paper: ELK-Full reaches 94.84% of Ideal on average; we assert a
        conservative >= 85% on this model."""
        frac = plans["Ideal"].total_time / plans["ELK-Full"].total_time
        assert frac >= 0.85

    def test_breakdown_sums_to_total(self, plans):
        for name, p in plans.items():
            if name == "Ideal":
                continue
            assert p.breakdown.total == pytest.approx(
                p.total_time, rel=0.35), name

    def test_utilizations_bounded(self, plans):
        for p in plans.values():
            assert 0 <= p.util.hbm <= 1
            assert 0 <= p.util.interconnect <= 1
            assert 0 <= p.util.flops <= 1
