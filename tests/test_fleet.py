"""Fleet-tier serving (DESIGN.md §12): the SLO-aware router over
prefill/decode-disaggregated pods.  A one-mixed-pod fleet is value-
identical to the direct batcher; cross-pod KV migration is bit-identical
and charged (plan within 2x of the fleet simulator); shedding against the
predicted TTFT keeps admitted p99 under the target; and the trace/summary
tooling grows multi-tenant knobs without disturbing old outputs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chip.config import ipu_pod4_hbm
from repro.chip.dse import fleet_sweep
from repro.chip.simulator import simulate_fleet_traffic
from repro.chip.topology import FleetSpec, fleet_spec
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve.batcher import (ContinuousBatcher, Request, make_trace,
                                 summarize)
from repro.serve.engine import (PREFILL_SAT, ServeConfig, ServeEngine,
                                elk_serve_config)
from repro.serve.fleet import (FleetPod, FleetRouter, PodCosts,
                               VirtualClock, predict_fleet_rates,
                               prefill_passes, run_virtual_trace)

COSTS = PodCosts(decode_step_s=1e-3, tick_overhead_s=5e-4)


def _engine(mesh, cfg, rng, **kw):
    params = T.init_params(rng, cfg)
    scfg = ServeConfig(**{"batch": 2, "cache_capacity": 64,
                          "prefill_chunk": 8, **kw})
    return ServeEngine(cfg, mesh, params, scfg)


def _solo(eng, prompt, steps):
    """Cold-path greedy reference for one request."""
    return np.asarray(eng.generate(
        jnp.tile(jnp.asarray(prompt)[None, :], (eng.scfg.batch, 1)),
        steps=steps))[0]


def _trace(cfg, n=6, **kw):
    return make_trace(n, vocab_size=cfg.vocab_size,
                      **{"prompt_lens": (8, 12, 16), "max_new": (3, 4, 5),
                         **kw})


class TestFleetSpec:
    def test_homogeneous_fleet_derives_inter_pod_tier(self):
        fl = fleet_spec(ipu_pod4_hbm(), 4)
        assert fl.num_pods == 4
        # the fleet tier is thinner and slower than any pod's own fabric
        assert 0 < fl.inter_pod_bw < min(p.topo.bisection_bw
                                         for p in fl.pods)
        assert fl.inter_pod_latency > max(p.link_latency for p in fl.pods)
        assert fl.link().name == "pod"

    def test_migration_spans_three_legs(self):
        fl = fleet_spec(ipu_pod4_hbm(), 2)
        nbytes = 1 << 20
        wire = fl.transfer_time(nbytes)
        mig = fl.migration_time(nbytes, 0, 1)
        assert wire > 0 and mig > wire     # offload + refill on top
        assert fl.transfer_time(0) == 0.0

    def test_rejects_degenerate_shapes(self):
        with pytest.raises(ValueError):
            fleet_spec(ipu_pod4_hbm(), 0)
        with pytest.raises(ValueError):
            FleetSpec(pods=())

    def test_signature_distinguishes_fleet_tier(self):
        a = fleet_spec(ipu_pod4_hbm(), 2)
        b = dataclasses.replace(a, inter_pod_bw=a.inter_pod_bw / 2)
        assert a.signature() != b.signature()


class TestPodCosts:
    def test_tick_cost_arithmetic(self):
        c = PodCosts(decode_step_s=1.0, tick_overhead_s=0.5,
                     prefill_sat=128, spill_s=0.25)
        assert c.tick_cost(decoded=False, prefill_tokens=0) == 0.5
        assert c.tick_cost(decoded=True, prefill_tokens=0) == 1.5
        # any chunk up to the saturating pass costs one weight pass
        assert c.tick_cost(decoded=True, prefill_tokens=16) == 2.5
        assert c.tick_cost(decoded=True, prefill_tokens=128) == 2.5
        assert c.tick_cost(decoded=False, prefill_tokens=129) == 2.5
        assert c.tick_cost(decoded=False, prefill_tokens=0,
                           spill_moves=2) == 1.0

    def test_from_serve_config_prefers_plan_interval(self):
        scfg = ServeConfig(batch=2, cache_capacity=64,
                           steady_interval_s=2e-3, slot_spill_s=1e-4)
        c = PodCosts.from_serve_config(scfg)
        assert c.decode_step_s == 2e-3
        assert c.tick_overhead_s == pytest.approx(1e-3)
        assert c.spill_s == 1e-4
        # no plan interval -> nominal decode quantum
        assert PodCosts.from_serve_config(
            ServeConfig(batch=2, cache_capacity=64)).decode_step_s == 1e-3

    def test_prefill_passes_replays_pow2_chunking(self):
        # 96 @ budget 16: 6 full chunks; @ budget 128: 64 + 32
        assert prefill_passes(96, 16) == 6
        assert prefill_passes(96, 128) == 2
        assert prefill_passes(1, 16) == 1
        assert prefill_passes(0, 16) == 0


class TestDegenerateFleet:
    def test_one_mixed_pod_equals_direct_batcher(self, mesh11, rng):
        """The acceptance pin: a FleetRouter over one mixed pod must be a
        pure pass-through — same completions (tokens, timestamps, order)
        and same summary as driving the batcher directly on the same
        virtual clock."""
        cfg = get_smoke_config("qwen3_14b")
        fr = FleetRouter([FleetPod(_engine(mesh11, cfg, rng), "mixed",
                                   costs=COSTS)])
        got = fr.run(_trace(cfg, arrival_spacing_s=2e-3))

        vc = VirtualClock()
        bat = ContinuousBatcher(_engine(mesh11, cfg, rng), vc)
        ref = run_virtual_trace(bat, _trace(cfg, arrival_spacing_s=2e-3),
                                COSTS)
        assert [c.rid for c in got] == [c.rid for c in ref]
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            assert a.finish_s == pytest.approx(b.finish_s, abs=1e-12)
            assert a.first_token_s == pytest.approx(b.first_token_s,
                                                    abs=1e-12)
            assert a.finish_order == b.finish_order
        direct = summarize(ref, vc.t)
        merged = fr.summary()
        for k, v in direct.items():
            assert merged[k] == v, k
        assert merged["routed"] == [len(ref)]
        assert merged["migrations"] == 0 and merged["shed"] == 0

    def test_router_validates_roles(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        with pytest.raises(ValueError):
            FleetRouter([FleetPod(eng, "decode")])
        with pytest.raises(ValueError):
            FleetRouter([FleetPod(eng, "warp")])
        with pytest.raises(ValueError):
            FleetRouter([])


class TestMigration:
    def test_cross_pod_offload_refill_is_bit_identical(self, mesh11, rng):
        """The primitive under the fleet tier: offload a slot on pod A,
        refill it on a *different engine* B, and the continued decode
        equals the never-migrated stream."""
        cfg = get_smoke_config("qwen3_14b")
        ea = _engine(mesh11, cfg, rng)
        eb = _engine(mesh11, cfg, rng)
        prompt = np.asarray(
            jax.random.randint(rng, (1, 9), 0, cfg.vocab_size))
        ref = _solo(ea, prompt[0], 6)[9:]

        tok, rc = ea.prefill_chunk(ea.new_request_cache(),
                                   jnp.asarray(prompt))
        ea.insert_slot(0, rc)
        state = ea.offload_slot(0)
        eb.refill_slot(1, state)        # different pod, different slot
        toks = jnp.zeros((2,), jnp.int32).at[1].set(tok[0])
        got = [int(tok[0])]
        for _ in range(5):
            toks = eb.step(toks)
            got.append(int(toks[1]))
        np.testing.assert_array_equal(np.asarray(got, np.int32), ref)

    def test_disagg_fleet_preserves_greedy_parity(self, mesh11, rng):
        """End-to-end through the router: every request served by the
        prefill->migrate->decode path produces the tokens of serving it
        alone, and TTFT comes from the prefill pod (first token exists
        before the migration lands)."""
        cfg = get_smoke_config("qwen3_14b")
        ref_eng = _engine(mesh11, cfg, rng)
        fr = FleetRouter(
            [FleetPod(_engine(mesh11, cfg, rng, prefill_chunk=64),
                      "prefill", costs=COSTS),
             FleetPod(_engine(mesh11, cfg, rng), "decode", costs=COSTS)])
        trace = _trace(cfg)
        got = fr.run(_trace(cfg))
        assert len(got) == len(trace)
        assert fr.migrations == len(trace)
        by_rid = {r.rid: r for r in trace}
        for c in got:
            r = by_rid[c.rid]
            np.testing.assert_array_equal(
                c.tokens, _solo(ref_eng, r.prompt, r.max_new_tokens))
            assert 0 <= c.first_token_s < c.finish_s

    def test_migration_is_charged_and_sim_matches_plan(self, mesh11, rng):
        """Acceptance gate: migration is not free — a fleet-priced router
        records planned wire+endpoint time per migration, and the fleet
        simulator re-serves the same event list within 2x of the plan
        (it only *adds* queueing, so the ratio can only push up)."""
        cfg = get_smoke_config("qwen3_14b")
        fl = fleet_spec(ipu_pod4_hbm(), 2)
        fr = FleetRouter(
            [FleetPod(_engine(mesh11, cfg, rng, prefill_chunk=64),
                      "prefill", costs=COSTS),
             FleetPod(_engine(mesh11, cfg, rng), "decode", costs=COSTS)],
            fleet=fl)
        fr.run(_trace(cfg))
        assert fr.migrations > 0
        assert fr.planned_migration_s > 0
        assert len(fr.migration_events) == fr.migrations
        res = simulate_fleet_traffic(fl, fr.migration_events)
        sim = sum(f - at for f, (_, at, _, _) in
                  zip(res.finish, fr.migration_events))
        ratio = sim / fr.planned_migration_s
        assert 0.5 <= ratio <= 2.0, ratio
        assert res.busy["fleet"] > 0

    def test_unpriced_fleet_migrates_for_free_but_counts(self, mesh11,
                                                         rng):
        cfg = get_smoke_config("qwen3_14b")
        fr = FleetRouter(
            [FleetPod(_engine(mesh11, cfg, rng, prefill_chunk=64),
                      "prefill", costs=COSTS),
             FleetPod(_engine(mesh11, cfg, rng), "decode", costs=COSTS)])
        fr.run(_trace(cfg))
        assert fr.migrations > 0
        assert fr.planned_migration_s == 0.0
        assert fr.migration_events == []


class TestSLO:
    def test_shedding_keeps_admitted_p99_under_target(self, mesh11, rng):
        """Acceptance pin: a burst that would blow the target gets shed
        down to what the pod can serve in time — admitted p99 TTFT meets
        the target at reduced admitted throughput; without the SLO the
        same burst all completes (and violates it)."""
        cfg = get_smoke_config("qwen3_14b")
        n = 8
        burst = _trace(cfg, n=n, prompt_lens=(32,), max_new=(3,))
        slo = 15e-3
        fr = FleetRouter([FleetPod(_engine(mesh11, cfg, rng, batch=1,
                                           prefill_chunk=16),
                                   "mixed", costs=COSTS)],
                         ttft_slo_s=slo)
        done = fr.run(burst)
        assert 0 < len(done) < n            # shed some, served some
        assert len(fr.shed) == n - len(done)
        assert max(c.ttft_s for c in done) <= slo + 1e-9
        assert fr.summary()["shed"] == len(fr.shed)

        fr2 = FleetRouter([FleetPod(_engine(mesh11, cfg, rng, batch=1,
                                            prefill_chunk=16),
                                    "mixed", costs=COSTS)])
        done2 = fr2.run(_trace(cfg, n=n, prompt_lens=(32,), max_new=(3,)))
        assert len(done2) == n              # no SLO: everything completes
        assert max(c.ttft_s for c in done2) > slo

    def test_prediction_upper_bounds_realized_ttft(self, mesh11, rng):
        """The shedding decision is only sound if predict_ttft never
        under-estimates: route a staggered trace and check every realized
        TTFT against the prediction made at routing time."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        fr = FleetRouter([FleetPod(eng, "mixed", costs=COSTS)])
        preds = {}
        orig = fr.predict_ttft

        def spy(index, plen, now):
            t = orig(index, plen, now)
            preds.setdefault((index, plen, round(now, 9)), []).append(t)
            return t

        fr.predict_ttft = spy
        trace = _trace(cfg, arrival_spacing_s=1e-3)
        done = fr.run(trace)
        by_rid = {r.rid: r for r in trace}
        for c in done:
            plen = len(by_rid[c.rid].prompt)
            pred = max(t for (_, p, _), ts in preds.items()
                       for t in ts if p == plen)
            assert c.ttft_s <= pred + 1e-9


class TestMultiTenantTrace:
    def test_defaults_reproduce_old_traces_byte_identically(self):
        cfg = get_smoke_config("qwen3_14b")
        old = make_trace(8, vocab_size=cfg.vocab_size, seed=3,
                         arrival_spacing_s=0.01, burst=2)
        new = make_trace(8, vocab_size=cfg.vocab_size, seed=3,
                         arrival_spacing_s=0.01, burst=2,
                         tenant_rates=(), tail_frac=0.0)
        for a, b in zip(old, new):
            np.testing.assert_array_equal(a.prompt, b.prompt)
            assert (a.rid, a.max_new_tokens, a.arrival_s, a.tenant) == \
                (b.rid, b.max_new_tokens, b.arrival_s, b.tenant)
            assert a.tenant == 0

    def test_tenant_rates_label_and_merge_poisson(self):
        cfg = get_smoke_config("qwen3_14b")
        reqs = make_trace(400, vocab_size=cfg.vocab_size, seed=5,
                          arrival_spacing_s=0.01,
                          tenant_rates=(3.0, 1.0))
        labels = np.asarray([r.tenant for r in reqs])
        assert set(labels) == {0, 1}
        # labels follow the rate shares (3:1)
        assert 0.6 < (labels == 0).mean() < 0.9
        arr = np.asarray([r.arrival_s for r in reqs])
        assert (np.diff(arr) >= 0).all() and arr[0] > 0
        # exponential gaps with the requested mean
        assert np.mean(np.diff(arr)) == pytest.approx(0.01, rel=0.3)
        # seeded: same knobs -> same trace
        again = make_trace(400, vocab_size=cfg.vocab_size, seed=5,
                           arrival_spacing_s=0.01,
                           tenant_rates=(3.0, 1.0))
        assert [r.arrival_s for r in again] == [r.arrival_s for r in reqs]
        with pytest.raises(ValueError):
            make_trace(4, vocab_size=8, tenant_rates=(1.0, 0.0))

    def test_tail_frac_stretches_and_caps_prompts(self):
        cfg = get_smoke_config("qwen3_14b")
        base = make_trace(200, vocab_size=cfg.vocab_size, seed=7,
                          prompt_lens=(16,))
        tailed = make_trace(200, vocab_size=cfg.vocab_size, seed=7,
                            prompt_lens=(16,), tail_frac=0.3,
                            tail_mult=4.0)
        lens_b = np.asarray([len(r.prompt) for r in base])
        lens_t = np.asarray([len(r.prompt) for r in tailed])
        assert (lens_b == 16).all()
        stretched = lens_t > 16
        assert 0.1 < stretched.mean() < 0.5      # ~tail_frac of them
        assert lens_t.max() <= 64                # capped at tail_mult x
        # untouched requests keep the grid length
        assert (lens_t[~stretched] == 16).all()


class TestSummarize:
    def test_new_keys_ride_alongside_old_ones(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        vc = VirtualClock()
        bat = ContinuousBatcher(_engine(mesh11, cfg, rng), vc)
        done = run_virtual_trace(bat, _trace(cfg, arrival_spacing_s=1e-3),
                                 COSTS)
        s = summarize(done, vc.t)
        for k in ("requests", "wall_s", "gen_tok_s", "p50_latency_s",
                  "p99_latency_s", "p50_ttft_s", "p99_ttft_s"):
            assert k in s                       # pre-existing keys intact
        assert s["p99_queue_wait_s"] >= s["p50_queue_wait_s"] >= 0
        gen = sum(len(c.tokens) - c.prompt_len for c in done)
        span = (max(c.finish_s for c in done)
                - min(c.first_token_s for c in done
                      if c.first_token_s >= 0))
        assert s["decode_tok_s"] == pytest.approx(gen / span, abs=0.01)
        # steady decode rate excludes the queue-drain ramp: >= whole-wall
        assert s["decode_tok_s"] >= s["gen_tok_s"]

    def test_queue_wait_from_admission_stamp(self):
        from repro.serve.batcher import Completion
        c = Completion(rid=0, tokens=np.zeros(4, np.int32), prompt_len=2,
                       arrival_s=1.0, finish_s=3.0, finish_order=0,
                       admitted_s=1.5)
        assert c.queue_wait_s == pytest.approx(0.5)
        c2 = dataclasses.replace(c, admitted_s=-1.0)
        assert c2.queue_wait_s == 0.0


class TestRoleSizing:
    def test_prefill_role_opens_chunk_budget(self, mesh11):
        cfg = get_smoke_config("qwen3_14b")
        mixed = elk_serve_config(cfg, batch=2, cache_capacity=256)
        pf = elk_serve_config(cfg, batch=2, cache_capacity=256,
                              role="prefill")
        dec = elk_serve_config(cfg, batch=2, cache_capacity=256,
                               role="decode")
        assert pf.prefill_chunk == min(PREFILL_SAT, 256)
        assert pf.prefill_chunk >= mixed.prefill_chunk
        assert dec.prefill_chunk == 16
        # mixed is byte-identical to the role-less call
        assert mixed == elk_serve_config(cfg, batch=2, cache_capacity=256,
                                         role="mixed")
        with pytest.raises(ValueError):
            elk_serve_config(cfg, batch=2, cache_capacity=256,
                             role="router")


class TestFleetSweep:
    def test_smoke_rows_and_disagg_verdict(self):
        rows = fleet_sweep(smoke=True, prompt_len=1024,
                           n_prefill_list=(1, 2),
                           inter_bw_ratios=(0.25,))
        assert len(rows) == 2
        for r in rows:
            assert r["migration_ms"] > 0
            assert r["disagg_prefill_req_s"] > r["mixed_prefill_req_s"]
        # the 1-prefill split keeps more decode pods than mixed pays in
        # interference -> wins both axes at long prompts
        one = next(r for r in rows if r["n_prefill"] == 1)
        assert one["disagg_won"]

    def test_predict_fleet_rates_validates_split(self):
        with pytest.raises(ValueError):
            predict_fleet_rates(COSTS, num_pods=4, n_prefill=0, slots=4,
                                prompt_len=64)
        with pytest.raises(ValueError):
            predict_fleet_rates(COSTS, num_pods=4, n_prefill=4, slots=4,
                                prompt_len=64)
