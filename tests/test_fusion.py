"""Inter-core fusion pass tests (DESIGN.md §8).

Covers chain detection across architectures (GLU / plain+bias / RWKV
channel-mix / MoE shared expert), the structural exclusions (residual-
stream norms, recurrences, attention BMMs), the aggregate-SRAM gate,
graph rewrite bookkeeping (preload_dep remap, layer_span), the fused
Pareto curve, and the compile-level selection contract the ISSUE pins:
fusion-on is never worse than fusion-off on any curated config and
strictly better on dit_xl prefill, with the event simulator agreeing
with the planner within 2x.
"""

import dataclasses

import pytest

from repro.chip.config import ipu_pod4_hbm
from repro.chip.simulator import simulate
from repro.configs import get_config
from repro.core.elk import compare_designs, compile_model
from repro.core.fusion import (FusedOp, enumerate_fused_exec_plans,
                               find_fusable_chains, fuse_graph,
                               fusion_signature, graph_fusion_signature)
from repro.core.graph import build_graph
from repro.core.partition import op_curve_signature
from repro.core.pipeline import CompileContext, clear_plan_cache

CHIP = ipu_pod4_hbm()

# the ISSUE's curated configs: (name, phase, seq)
CURATED = [("dit_xl", "prefill", 256), ("opt_30b", "prefill", 512),
           ("llama2_13b", "prefill", 512), ("rwkv6_7b", "prefill", 512)]


def _graph(name, layers=2, batch=1, seq=128, phase="prefill"):
    cfg = dataclasses.replace(get_config(name), num_layers=layers)
    return build_graph(cfg, batch=batch, seq=seq, phase=phase)


def _chain_names(g, chains):
    return sorted({" + ".join(o.name.split(".", 1)[-1] for o in g.ops[s:e])
                   for s, e in chains})


# ---------------------------------------------------------------------------
# chain detection
# ---------------------------------------------------------------------------

class TestChainDetection:
    def test_glu_chain(self):
        g = _graph("llama2_13b")
        chains = find_fusable_chains(g, CHIP)
        assert _chain_names(g, chains) == ["gate_up + act + down"]
        assert len(chains) == 2          # one per layer

    def test_plain_chain_with_bias(self):
        g = _graph("opt_30b")
        chains = find_fusable_chains(g, CHIP)
        assert _chain_names(g, chains) == ["fc1 + act + fc2"]

    def test_rwkv_channel_mix_only(self):
        """The channel-mix MLP fuses; the wkv recurrence (from_hbm state
        input) must not."""
        g = _graph("rwkv6_7b")
        chains = find_fusable_chains(g, CHIP)
        assert _chain_names(g, chains) == ["cm_k + cm_act + cm_v"]

    def test_moe_shared_expert_fuses_router_does_not(self):
        """llama4: the shared-expert MLP is a fusable chain, but
        o -> ln2 -> router (a residual-stream norm feeding a square-ish
        projection) must be rejected by the hourglass rule."""
        g = _graph("llama4_maverick_400b_a17b")
        names = _chain_names(g, find_fusable_chains(g, CHIP))
        assert "shared_up + shared_act + shared_down" in names
        assert all("router" not in n and "ln" not in n for n in names)

    def test_attention_ops_never_fuse(self):
        for name in ("llama2_13b", "opt_30b", "dit_xl"):
            g = _graph(name)
            for s, e in find_fusable_chains(g, CHIP):
                for op in g.ops[s:e]:
                    assert "qk" not in op.name and "softmax" not in op.name
                    assert "av" not in op.name.split(".")[-1][:2]

    def test_sram_gate(self):
        """A chip too small to hold the chain's intermediate in aggregate
        SRAM fuses nothing."""
        tiny = dataclasses.replace(CHIP, num_cores=2)
        g = _graph("opt_30b", seq=512)
        assert find_fusable_chains(g, tiny) == []
        assert fuse_graph(g, tiny) is g          # same object, no rewrite


# ---------------------------------------------------------------------------
# graph rewrite bookkeeping
# ---------------------------------------------------------------------------

class TestFuseGraph:
    def test_op_count_and_layer_span(self):
        g = _graph("llama2_13b")
        f = fuse_graph(g, CHIP)
        chains = find_fusable_chains(g, CHIP)
        assert len(f.ops) == len(g.ops) - sum(e - s - 1 for s, e in chains)
        s, e = f.layer_span
        layers = {op.layer for op in f.ops[s:e]}
        assert layers == {g.ops[g.layer_span[0]].layer}

    def test_preload_dep_remap(self):
        """MoE late-binding deps must point at the same op after the
        rewrite shifts indices."""
        g = _graph("llama4_maverick_400b_a17b")
        f = fuse_graph(g, CHIP)
        old = {op.name: g.ops[op.preload_dep].name
               for op in g.ops if op.preload_dep >= 0}
        new = {op.name.split("+")[0]: f.ops[op.preload_dep].name
               for op in f.ops if op.preload_dep >= 0}
        for name, dep in new.items():
            if name in old:
                assert old[name].split("+")[0] in dep

    def test_fused_op_shape_accounting(self):
        g = _graph("llama2_13b")
        f = fuse_graph(g, CHIP)
        fused = [op for op in f.ops if isinstance(op, FusedOp)]
        assert fused
        for op in fused:
            a, b, c = op.parts
            assert op.flops == a.flops + b.flops + c.flops
            assert op.out_bytes == c.out_bytes
            assert op.inter_bytes == max(a.out_bytes, b.out_bytes)
            # both weight tensors stream from HBM: one merged preload
            assert all(t.from_hbm for t in op.inputs[1:])
            assert (sum(t.bytes_total for t in op.inputs[1:])
                    == sum(t.bytes_total for p in (a, c)
                           for t in p.inputs[1:]))

    def test_name_suffix_layer_invariant(self):
        """§4.4 order replay keys on name.split('.', 1)[-1]; fused names
        must stay identical across identical layers."""
        g = _graph("llama2_13b", layers=3)
        f = fuse_graph(g, CHIP)
        suffixes = {op.name.split(".", 1)[-1] for op in f.ops
                    if isinstance(op, FusedOp)}
        assert len(suffixes) == 1


# ---------------------------------------------------------------------------
# fused Pareto curve
# ---------------------------------------------------------------------------

class TestFusedCurve:
    def _fused_op(self, name="dit_xl", seq=256):
        f = fuse_graph(_graph(name, seq=seq), CHIP)
        return next(op for op in f.ops if isinstance(op, FusedOp))

    def test_curve_carries_both_alternatives(self):
        op = self._fused_op()
        curve = enumerate_fused_exec_plans(op, CHIP)
        assert any(p.fused for p in curve)
        assert any(not p.fused for p in curve)
        # fastest/biggest first, strictly improving down-curve in space
        for a, b in zip(curve, curve[1:]):
            assert a.space >= b.space and a.time <= b.time

    def test_feasible_and_signature(self):
        op = self._fused_op()
        curve = enumerate_fused_exec_plans(op, CHIP)
        cap = CHIP.usable_sram_per_core
        assert all(p.space <= cap for p in curve)
        sig = op_curve_signature(op)
        assert any("fused" in str(part) for part in sig)
        assert sig != op_curve_signature(op.parts[0])

    def test_fused_point_beats_composed_at_same_footprint(self):
        """On an overhead-dominated op the fastest fused point must beat
        the fastest composed point (in-stream activation vs a separate
        vector op)."""
        op = self._fused_op()
        curve = enumerate_fused_exec_plans(op, CHIP)
        best_f = min((p.time for p in curve if p.fused), default=None)
        best_c = min(p.time for p in curve if not p.fused)
        assert best_f is not None and best_f < best_c


# ---------------------------------------------------------------------------
# compile-level selection: the ISSUE acceptance pins
# ---------------------------------------------------------------------------

class TestSelection:
    @pytest.fixture(scope="class")
    def plans(self):
        out = {}
        for name, phase, seq in CURATED:
            cfg = dataclasses.replace(get_config(name), num_layers=4)
            ctx = CompileContext(CHIP)
            kw = dict(batch=1, seq=seq, phase=phase, ctx=ctx, cache=False)
            out[name] = (compile_model(cfg, CHIP, **kw),
                         compile_model(cfg, CHIP, fusion=True, **kw))
        return out

    def test_never_worse_on_any_curated_config(self, plans):
        for name, (off, on) in plans.items():
            assert on.total_time <= off.total_time * (1 + 1e-12), name
            assert off.fusion is False

    def test_fusion_wins_on_dit_xl_prefill(self, plans):
        off, on = plans["dit_xl"]
        assert on.fusion is True
        assert any(isinstance(op, FusedOp) for op in on.graph.ops)
        # a genuine improvement, not float noise (ISSUE: "improves on at
        # least one compute-intensive config")
        assert on.total_time < off.total_time * 0.995

    def test_fused_schedule_executes_fused_points(self, plans):
        _, on = plans["dit_xl"]
        fused_idx = {i for i, op in enumerate(on.graph.ops)
                     if isinstance(op, FusedOp)}
        picked = [d.exec_plan.fused for d in on.decisions
                  if d.op_idx in fused_idx]
        assert picked and all(picked)

    def test_simulator_within_2x_of_planner(self, plans):
        for name, (_, on) in plans.items():
            sim = simulate(on, CHIP)
            ratio = sim.total_time / on.total_time
            assert 0.5 <= ratio <= 2.0, (name, ratio)

    def test_selection_returns_distinct_objects(self, plans):
        for name, (off, on) in plans.items():
            assert off is not on
            assert on.fusion == any(isinstance(op, FusedOp)
                                    for op in on.graph.ops)

    def test_compare_designs_knob(self):
        cfg = dataclasses.replace(get_config("dit_xl"), num_layers=2)
        res = compare_designs(cfg, CHIP, batch=1, seq=256, phase="prefill",
                              designs=("Static", "ELK-Full"), fusion=True,
                              cache=False)
        assert set(res) == {"Static", "ELK-Full"}
        for plan in res.values():
            assert isinstance(plan.fusion, bool)


# ---------------------------------------------------------------------------
# cache signatures
# ---------------------------------------------------------------------------

class TestSignatures:
    def test_fusion_signature_distinguishes_knob(self):
        assert fusion_signature(True) != fusion_signature(False)

    def test_graph_signature_distinguishes_fused_graph(self):
        g = _graph("llama2_13b")
        f = fuse_graph(g, CHIP)
        assert graph_fusion_signature(g) != graph_fusion_signature(f)

    def test_identical_layer_chains_share_curve_signature(self):
        clear_plan_cache()
        f = fuse_graph(_graph("llama2_13b", layers=3), CHIP)
        sigs = {op_curve_signature(op) for op in f.ops
                if isinstance(op, FusedOp)}
        assert len(sigs) == 1            # one curve serves every layer
