"""Hybrid parallelism planner tests (DESIGN.md §9).

Covers the issue's acceptance criteria and satellites:

* degenerate equivalence — hybrid pinned to ``widths=(1,)`` /
  ``replicas=(1,)`` is bit-identical to ``mode="pipeline"``
  (property-tested over shapes), and a one-chip pod degenerates to the
  flat single-chip compile;
* ``shard_graph`` conservation — per-chip FLOPs and HBM bytes divide by
  the tensor-parallel width (up to ceil rounding), dense models pay only
  all-reduces, MoE models an expert-dispatch all-to-all pair;
* never-worse — the joint search never returns a plan with a worse
  per-request round time than the pure pipeline it always evaluates;
* acceptance pin — hybrid beats pure pipeline on full opt_30b decode on
  the 4-chip ``hier_pod`` (the PR 4 pipeline pin stays reproduced);
* simulator agreement — ``simulate_pipeline`` prices replica servers and
  intra-stage collectives and stays within 2x of the hybrid planner on
  every shipped topology;
* cache keys — tensor-parallel width is part of the plan/stage cache
  signatures, so different widths can never alias.
"""

import dataclasses

import pytest

from repro.chip.config import ipu_pod4_hbm
from repro.chip.simulator import simulate_pipeline
from repro.chip.topology import TOPOLOGIES
from repro.configs import get_config
from repro.core.elk import compile_model
from repro.core.graph import build_graph
from repro.core.integration import pod_plan
from repro.core.pipeline_pod import plan_hybrid, plan_pipeline, shard_graph

POD = ipu_pod4_hbm(topology="hier_pod")


def tiny_cfg(num_layers: int = 4, **kw):
    return dataclasses.replace(get_config("opt_30b"),
                               num_layers=num_layers, **kw)


def plans_equal(a, b) -> bool:
    """Bit-identical schedules: same timings, same per-op plan choices."""
    if a.total_time != b.total_time or a.preload_order != b.preload_order:
        return False
    for da, db in zip(a.decisions, b.decisions):
        if da.exec_plan.key() != db.exec_plan.key():
            return False
        fa = da.preload_plan.frac if da.preload_plan else None
        fb = db.preload_plan.frac if db.preload_plan else None
        if fa != fb:
            return False
    return True


# ---------------------------------------------------------------------------
# degenerate equivalence (satellite, property-tested)
# ---------------------------------------------------------------------------

class TestDegenerateEquivalence:
    @pytest.mark.parametrize("num_layers,batch,seq", [
        (2, 8, 256), (4, 8, 256), (4, 32, 512), (8, 16, 256)])
    def test_width1_replica1_is_pure_pipeline(self, num_layers, batch, seq):
        cfg = tiny_cfg(num_layers)
        hp = plan_hybrid(cfg, POD, batch=batch, seq=seq, max_orders=2,
                         widths=(1,), replicas=(1,))
        pp = plan_pipeline(cfg, POD, batch=batch, seq=seq, max_orders=2)
        assert hp.num_stages == pp.num_stages
        assert hp.microbatch == pp.microbatch
        assert hp.microbatches == pp.microbatches
        assert hp.interval == pp.interval
        assert hp.batch_interval == pp.batch_interval
        assert hp.fill_time == pp.fill_time
        for a, b in zip(hp.stages, pp.stages):
            assert a.layers == b.layers
            assert a.width == 1 and a.replicas == 1
            assert a.collective_time == 0.0 and a.collectives == ()
            assert plans_equal(a.plan, b.plan)

    def test_single_chip_pod_is_flat_compile(self):
        cfg = tiny_cfg()
        pod1 = dataclasses.replace(
            POD, num_chips=1, num_cores=POD.cores_per_chip,
            hbm_bw=POD.hbm_bw / 4, hbm_controllers=4)
        hp = plan_hybrid(cfg, pod1, batch=8, seq=256)
        ref = compile_model(cfg, pod1, batch=8, seq=256, phase="decode",
                            design="ELK-Full", max_orders=4)
        assert hp.num_stages == 1 and hp.microbatches == 1
        assert hp.stages[0].width == 1 and hp.stages[0].replicas == 1
        assert plans_equal(hp.stages[0].plan, ref)
        assert hp.interval == ref.total_time

    def test_sim_identical_for_degenerate_plan(self):
        """simulate_pipeline's replica/collective terms are exact no-ops
        on a width-1/replica-1 plan."""
        cfg = tiny_cfg(4)
        hp = plan_hybrid(cfg, POD, batch=8, seq=256, max_orders=2,
                         widths=(1,), replicas=(1,))
        pp = plan_pipeline(cfg, POD, batch=8, seq=256, max_orders=2)
        sh, sp = simulate_pipeline(hp, POD), simulate_pipeline(pp, POD)
        assert sh.interval == sp.interval
        assert sh.total_time == sp.total_time


# ---------------------------------------------------------------------------
# shard_graph: conservation + collective shapes
# ---------------------------------------------------------------------------

class TestShardGraph:
    def test_dense_conservation_and_all_reduce_only(self):
        g = build_graph(tiny_cfg(2), batch=8, seq=256, phase="decode")
        lo, l0_end = g.layer_span            # [start, end) of layer 0
        hi = lo + g.num_layers * (l0_end - lo)
        flops0 = sum(op.flops for op in g.ops[lo:hi])
        hbm0 = sum(op.hbm_bytes for op in g.ops[lo:hi])
        for w in (2, 4):
            sg, colls = shard_graph(g, w)
            flops = sum(op.flops for op in sg.ops[lo:hi])
            hbm = sum(op.hbm_bytes for op in sg.ops[lo:hi])
            # per-chip layer work = 1/w of the whole, up to ceil rounding
            # and the replicated in-layer norms/router
            assert flops0 / w <= flops <= 1.15 * flops0 / w
            assert hbm0 / w <= hbm <= 1.15 * hbm0 / w
            # the prefix/suffix (embed, final norm, lm_head) is replicated
            for a, b in zip(g.ops[:lo] + g.ops[hi:],
                            sg.ops[:lo] + sg.ops[hi:]):
                assert a.flops == b.flops and a.hbm_bytes == b.hbm_bytes
            assert colls, "row-sharded projections must pay an all-reduce"
            assert {k for k, _ in colls} == {"all_reduce"}
            assert all(b > 0 for _, b in colls)
            assert sg.model.endswith(f"@tp{w}")

    def test_moe_gets_expert_all_to_all(self):
        cfg = dataclasses.replace(get_config("kimi_k2_1t_a32b"),
                                  num_layers=2)
        g = build_graph(cfg, batch=8, seq=256, phase="decode")
        sg, colls = shard_graph(g, 4)
        kinds = {k for k, _ in colls}
        # expert-parallel dispatch/combine + the dense projections' AR
        assert "all_to_all" in kinds and "all_reduce" in kinds
        # expert weights shard across the width: strictly less HBM traffic
        assert sum(op.hbm_bytes for op in sg.ops) < \
            sum(op.hbm_bytes for op in g.ops)

    def test_width_is_part_of_graph_identity(self):
        """Cache-key regression: a sharded graph can never alias the full
        graph or another width in the plan cache (its signature starts
        from the model name)."""
        g = build_graph(tiny_cfg(2), batch=8, seq=256, phase="decode")
        names = {g.model, shard_graph(g, 2)[0].model,
                 shard_graph(g, 4)[0].model}
        assert len(names) == 3


# ---------------------------------------------------------------------------
# never-worse + the acceptance pin
# ---------------------------------------------------------------------------

class TestHybridSearch:
    @pytest.mark.parametrize("topo", ("hier_pod", "ring"))
    def test_never_worse_than_pipeline(self, topo):
        cfg = tiny_cfg(4)
        pod = ipu_pod4_hbm(topology=topo)
        pp = plan_pipeline(cfg, pod, batch=8, seq=256, max_orders=2)
        hp = plan_hybrid(cfg, pod, batch=8, seq=256, max_orders=2)
        assert hp.batch_interval / hp.batch <= \
            pp.batch_interval / pp.batch * (1 + 1e-12)

    def test_hybrid_beats_pipeline_opt30b_4chip(self):
        """Acceptance: on full opt_30b decode over the 4-chip hier_pod,
        the joint search finds a strictly better per-request round time
        than the pure pipeline — and the PR 4 pipeline pin still holds."""
        cfg = get_config("opt_30b")
        pp = plan_pipeline(cfg, POD, batch=32, seq=2048)
        hp = plan_hybrid(cfg, POD, batch=32, seq=2048)
        assert pp.batch_interval == pytest.approx(20.55e-3, rel=1e-3)
        assert hp.batch_interval == pytest.approx(14.85e-3, rel=1e-2)
        assert hp.batch_interval / hp.batch < pp.batch_interval / pp.batch
        assert any(st.width > 1 or st.replicas > 1 for st in hp.stages)
        # the chips the plan claims exist: widths x replicas fill the pod
        assert sum(st.chips for st in hp.stages) == POD.num_chips

    def test_pinned_microbatches_respected(self):
        cfg = tiny_cfg(8)
        hp = plan_hybrid(cfg, POD, batch=32, seq=512, max_orders=2,
                         microbatches=2)
        assert hp.microbatches <= 2
        assert hp.microbatch * hp.microbatches >= 32


# ---------------------------------------------------------------------------
# simulator agreement (acceptance: within 2x on every shipped topology)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_hybrid_sim_within_2x(topo):
    cfg = tiny_cfg(8)
    pod = ipu_pod4_hbm(topology=topo)
    hp = plan_hybrid(cfg, pod, batch=32, seq=2048)
    sim = simulate_pipeline(hp, pod)
    ratio = sim.interval / hp.interval
    assert 0.5 <= ratio <= 2.0, (topo, ratio)


# ---------------------------------------------------------------------------
# pod_plan mode="hybrid" knobs
# ---------------------------------------------------------------------------

class TestPodPlanHybrid:
    def test_hybrid_mode_returns_width_knobs(self):
        cfg = tiny_cfg(8)
        k = pod_plan(cfg, batch=32, seq=2048, chip=POD, mode="hybrid")
        assert len(k.stage_widths) == k.num_stages
        assert len(k.stage_replicas) == k.num_stages
        assert sum(w * r for w, r in zip(k.stage_widths,
                                         k.stage_replicas)) == POD.num_chips
        assert k.microbatch * k.microbatches >= 32
        assert k.interval_s > 0
        assert k.batch_interval_s == pytest.approx(
            k.microbatches * k.interval_s)

    def test_pipeline_mode_stays_width1(self):
        cfg = tiny_cfg(8)
        k = pod_plan(cfg, batch=32, seq=2048, chip=POD, mode="pipeline")
        assert set(k.stage_widths) == {1}
        assert set(k.stage_replicas) == {1}


# ---------------------------------------------------------------------------
# cache keys (satellite: width pinned in plan cache signatures)
# ---------------------------------------------------------------------------

class TestCacheKeys:
    def test_width_axis_in_plan_cache_key(self):
        cfg = tiny_cfg(4)
        kw = dict(batch=8, seq=256, max_orders=2)
        a = plan_hybrid(cfg, POD, widths=(1,), replicas=(1,), **kw)
        b = plan_hybrid(cfg, POD, widths=(1, 2), replicas=(1,), **kw)
        c = plan_hybrid(cfg, POD, widths=(1,), replicas=(1,), **kw)
        assert a is c, "same search space must hit the plan cache"
        assert a is not b, "different width axes must not alias"
