"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles, all in
interpret=True mode (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.elk_matmul.kernel import elk_matmul
from repro.kernels.elk_matmul.ref import matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 6e-2}


def _tol(dtype, ref):
    return TOL[dtype] * (float(jnp.max(jnp.abs(ref.astype(jnp.float32))))
                         + 1.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 64, 512),
                                 (100, 60, 70), (33, 129, 257)])
def test_elk_matmul(mnk, dtype, rng):
    m, n, k = mnk
    x = jax.random.normal(rng, (m, k), dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = elk_matmul(x, y, bm=64, bn=64, bk=64, interpret=True)
    ref = matmul_ref(x, y)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err <= _tol(dtype, ref), (mnk, dtype, err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", [
    # (B, Hq, Hkv, S, D, causal, window)
    (2, 4, 2, 128, 32, True, 0),       # GQA causal
    (1, 4, 4, 128, 16, True, 48),      # MHA sliding window
    (1, 2, 1, 64, 32, False, 0),       # bidirectional MQA
    (1, 8, 8, 256, 64, True, 0),       # MHA causal, bigger head
])
def test_flash_attention(case, dtype, rng):
    b, hq, hkv, s, d, causal, win = case
    q = jax.random.normal(rng, (b, hq, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          bq=32, bk=32, interpret=True)
    ref = mha_ref(q, k, v, causal=causal, window=win)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err <= _tol(dtype, ref), (case, dtype, err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", [
    # (B, Hq, Hkv, C, D, window, pos)
    (2, 8, 2, 128, 32, 0, 100),        # partial cache
    (1, 4, 4, 256, 16, 64, 300),       # ring buffer + window
    (2, 2, 1, 64, 64, 0, 64),          # exactly full cache
])
def test_decode_attention(case, dtype, rng):
    b, hq, hkv, c, d, win, pos = case
    q = jax.random.normal(rng, (b, hq, d), dtype)
    kc = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, c, d), dtype)
    vc = jax.random.normal(jax.random.PRNGKey(5), (b, hkv, c, d), dtype)
    idx = jnp.arange(c)
    if pos <= c:
        slot_pos = jnp.where(idx < pos, idx, 2 ** 30)
    else:
        start = pos - c
        slot_pos = start + (idx - start) % c
    out = decode_attention(q, kc, vc, slot_pos, pos, window=win, bk=64,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, slot_pos, pos, window=win)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err <= _tol(dtype, ref), (case, dtype, err)


def test_flash_matches_model_attention(rng):
    """The kernel and the model's reference GQA path agree, so swapping the
    kernel in on TPU changes performance, not semantics."""
    from repro.models.layers import AttnSpec, attn_mask_bias, gqa_attention
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q = jax.random.normal(rng, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(7), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(8), (b, hkv, s, d), jnp.float32)
    spec = AttnSpec(hq, hkv, d, causal=True)
    pos = jnp.arange(s)
    bias = attn_mask_bias(spec, pos, pos)
    ref = gqa_attention(q, k, v, bias, spec)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                          interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", [
    # (M, D, FF, gated, bias, act)
    (128, 128, 256, True, False, "silu"),     # GLU, block-aligned
    (64, 96, 200, True, False, "silu"),       # GLU, non-multiple of bf
    (100, 80, 144, False, True, "relu"),      # plain + biases, ragged m
    (33, 64, 257, False, False, "gelu"),      # plain, everything ragged
])
def test_fused_mlp_kernel(case, dtype, rng):
    from repro.kernels.fused_mlp.kernel import fused_mlp_kernel
    from repro.kernels.fused_mlp.ref import composed_ref
    m, d, ff, gated, bias, act = case
    keys = jax.random.split(rng, 6)
    x = jax.random.normal(keys[0], (m, d), dtype)
    w_up = jax.random.normal(keys[1], (d, ff), dtype) / jnp.sqrt(d)
    w_down = jax.random.normal(keys[2], (ff, d), dtype) / jnp.sqrt(ff)
    kw = {}
    if gated:
        kw["w_gate"] = jax.random.normal(keys[3], (d, ff), dtype) / jnp.sqrt(d)
    if bias:
        kw["b_up"] = jax.random.normal(keys[4], (ff,), dtype)
        kw["b_down"] = jax.random.normal(keys[5], (d,), dtype)
    out = fused_mlp_kernel(x, w_up, w_down, act=act, bm=32, bf=128,
                           interpret=True, **kw)
    ref = composed_ref(x, w_up, w_down, act=act, **kw)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err <= _tol(dtype, ref), (case, dtype, err)


def test_fused_mlp_batched_lead_dims(rng):
    """The wrapper flattens (B, S, D) leads; parity must survive that."""
    from repro.kernels.fused_mlp.kernel import fused_mlp_kernel
    from repro.kernels.fused_mlp.ref import composed_ref
    x = jax.random.normal(rng, (2, 40, 64), jnp.float32)
    w_up = jax.random.normal(jax.random.PRNGKey(1), (64, 160), jnp.float32)
    w_gate = jax.random.normal(jax.random.PRNGKey(2), (64, 160), jnp.float32)
    w_down = jax.random.normal(jax.random.PRNGKey(3), (160, 64), jnp.float32)
    out = fused_mlp_kernel(x, w_up, w_down, w_gate=w_gate, act="silu",
                           bm=32, bf=64, interpret=True)
    ref = composed_ref(x, w_up, w_down, w_gate=w_gate, act="silu")
    assert out.shape == ref.shape
    assert float(jnp.max(jnp.abs(out - ref))) <= _tol(jnp.float32, ref)


def test_fused_mlp_matches_model_mlp(rng):
    """fused_mlp_ref is the exact einsum composition models.layers.mlp used
    before the fused path: CPU model outputs are bit-identical by
    construction, and the kernel agrees within kernel tolerance."""
    from repro.kernels.fused_mlp.kernel import fused_mlp_kernel
    from repro.kernels.fused_mlp.ref import fused_mlp_ref
    x = jax.random.normal(rng, (48, 64), jnp.float32)
    w_up = jax.random.normal(jax.random.PRNGKey(11), (64, 128), jnp.float32)
    w_gate = jax.random.normal(jax.random.PRNGKey(12), (64, 128), jnp.float32)
    w_down = jax.random.normal(jax.random.PRNGKey(13), (128, 64), jnp.float32)
    ref = fused_mlp_ref(x, w_up, w_down, w_gate=w_gate, act="silu")
    out = fused_mlp_kernel(x, w_up, w_down, w_gate=w_gate, act="silu",
                           bm=32, bf=64, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) <= _tol(jnp.float32, ref)


def test_vmem_plan_within_budget():
    from repro.core.integration import vmem_plan
    plan = vmem_plan(8192, 8192, 8192)
    assert plan.vmem_bytes <= 128 * 1024 * 1024
    assert plan.bm % 128 == 0 and plan.bn % 128 == 0 and plan.bk % 128 == 0
    # bigger budget must never increase HBM traffic
    small = vmem_plan(8192, 8192, 8192, vmem_budget=16 * 2 ** 20)
    assert plan.hbm_traffic <= small.hbm_traffic
