"""Dynamic KV-cache tier offload + prefix reuse (DESIGN.md §11): the
spillable slot API round-trips bit-identically, the oversubscribed batcher
preserves greedy parity while beating the capacity-capped scheduler on
tick count, the prefix store's cached-prefix output equals the cold path,
and the plan->K mapping keeps PR 8 configs value-identical."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chip.config import GB, ipu_mk2, ipu_pod4_hbm
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve.batcher import ContinuousBatcher, Request, make_trace, \
    run_static_trace, summarize
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.prefix import PrefixStore


def _engine(mesh, cfg, rng, **kw):
    params = T.init_params(rng, cfg)
    scfg = ServeConfig(**{"batch": 2, "cache_capacity": 64,
                          "prefill_chunk": 8, **kw})
    return ServeEngine(cfg, mesh, params, scfg)


def _solo(eng, prompt, steps):
    """Cold-path greedy reference for one request."""
    return np.asarray(eng.generate(
        jnp.tile(jnp.asarray(prompt)[None, :], (eng.scfg.batch, 1)),
        steps=steps))[0]


class TestSlotSpill:
    def test_evict_insert_round_trips_bit_identically(self, mesh11, rng):
        """evict_slot returns the evicted state (it used to discard it);
        re-inserting it must reproduce the uninterrupted decode stream and
        the exact cache leaves."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompt = jax.random.randint(rng, (1, 9), 0, cfg.vocab_size)
        ref = _solo(eng, np.asarray(prompt)[0], 6)[9:]

        tok, rc = eng.prefill_chunk(eng.new_request_cache(), prompt)
        eng.insert_slot(0, rc)
        toks = jnp.zeros((2,), jnp.int32).at[0].set(tok[0])
        got = [int(tok[0])]
        for i in range(5):
            if i == 2:      # interrupt mid-decode: evict, then re-insert
                state = eng.evict_slot(0)
                assert state is not None and "pos" in state
                before = {k: np.array(v) for k, v in state.items()}
                eng.insert_slot(0, state)
                after = eng.evict_slot(0)
                for k in before:
                    np.testing.assert_array_equal(
                        before[k], np.array(after[k]), err_msg=k)
                eng.insert_slot(0, after)
            toks = eng.step(toks)
            got.append(int(toks[0]))
        np.testing.assert_array_equal(np.asarray(got, np.int32), ref)

    def test_offload_refill_to_other_slot(self, mesh11, rng):
        """offload_slot hands back a host copy that refills into *any*
        slot and continues the stream bit-identically."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompt = jax.random.randint(rng, (1, 7), 0, cfg.vocab_size)
        ref = _solo(eng, np.asarray(prompt)[0], 6)[7:]

        tok, rc = eng.prefill_chunk(eng.new_request_cache(), prompt)
        eng.insert_slot(0, rc)
        toks = jnp.zeros((2,), jnp.int32).at[0].set(tok[0])
        got = [int(tok[0])]
        for _ in range(2):
            toks = eng.step(toks)
            got.append(int(toks[0]))
        state = eng.offload_slot(0)
        assert all(isinstance(v, np.ndarray)
                   for v in jax.tree.leaves(state))
        eng.refill_slot(1, state)
        toks = jnp.zeros((2,), jnp.int32).at[1].set(got[-1])
        for _ in range(3):
            toks = eng.step(toks)
            got.append(int(toks[1]))
        np.testing.assert_array_equal(np.asarray(got, np.int32), ref)

    def test_offload_is_a_real_copy(self, mesh11, rng):
        """The offloaded state must survive the donated engine steps that
        recycle the device buffers it was sliced from."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompt = jax.random.randint(rng, (1, 5), 0, cfg.vocab_size)
        tok, rc = eng.prefill_chunk(eng.new_request_cache(), prompt)
        eng.insert_slot(0, rc)
        state = eng.offload_slot(0)
        snap = {k: v.copy() for k, v in state.items()}
        for _ in range(3):      # recycle donated buffers
            eng.step(jnp.zeros((2,), jnp.int32))
        for k in snap:
            np.testing.assert_array_equal(snap[k], state[k], err_msg=k)

    def test_slot_state_bytes_matches_leaves(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        eng._ensure_slots()
        state = eng.offload_slot(0)
        nbytes = sum(v.nbytes for v in state.values())
        assert eng.slot_state_bytes() == nbytes


class TestOversubscription:
    def test_oversubscribed_parity_with_swaps(self, mesh11, rng):
        """2 physical slots, 6 requests in one burst, K=3: every stream
        must be bit-identical to running the request alone even though
        requests park offloaded and LRU swaps time-slice the slots."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng, oversub=3.0)
        reqs = make_trace(6, vocab_size=cfg.vocab_size,
                          prompt_lens=(6, 9, 12), max_new=(5, 8, 11),
                          seed=3)
        bat = ContinuousBatcher(eng, swap_after=2)
        assert bat.virtual_slots == 6
        out = {c.rid: c for c in bat.run(reqs)}
        assert len(out) == 6
        assert bat.spill_events, "no offload traffic despite 3x burst"
        for r in reqs:
            ref = _solo(eng, r.prompt, r.max_new_tokens)
            np.testing.assert_array_equal(out[r.rid].tokens, ref,
                                          err_msg=f"rid={r.rid}")

    def test_oversubscribed_beats_capped_on_ticks(self, mesh11, rng):
        """The acceptance mechanism, pinned deterministically: on a burst
        with >= 2x slot concurrency the oversubscribed scheduler finishes
        the same trace in strictly fewer ticks than the capacity-capped
        one (prefill-ahead keeps slots from idling while a new request
        prefills), hence strictly higher gen tok/s at equal per-tick
        cost."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        reqs = make_trace(8, vocab_size=cfg.vocab_size,
                          prompt_lens=(16, 24, 32, 24),
                          max_new=(4, 6, 8, 6), seed=5)
        capped = ContinuousBatcher(eng, oversub=1.0)
        capped.run(reqs)
        over = ContinuousBatcher(eng, oversub=4.0)
        out = over.run(reqs)
        assert len(out) == 8
        assert over.ticks < capped.ticks, (over.ticks, capped.ticks)

    def test_lru_victim_is_least_recently_resident(self, mesh11, rng):
        """With every active slot equally recent, the LRU swap evicts the
        longest-resident slot once a waiter has starved ``swap_after``
        ticks — and never before."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng, oversub=2.0)
        bat = ContinuousBatcher(eng, swap_after=3)
        reqs = [Request(i, np.asarray([3 + i, 5, 7 + i], np.int32), 40)
                for i in range(3)]
        for r in reqs:
            bat.submit(r)
        # ticks 0-1: requests 0 and 1 prefill into slots; tick 2: request
        # 2 prefills ahead and parks spilled; once it has starved
        # ``swap_after`` ticks the swap must give it a slot
        for _ in range(20):
            bat.tick()
            if 2 in {a.req.rid for a in bat.active.values()}:
                break
        else:
            pytest.fail("starving waiter never refilled")
        # request 2 entered via an LRU swap: the victim must have been the
        # longest-resident slot (request 0, admitted first)
        spilled_rids = {sp.req.rid for sp in bat.spilled.values()}
        assert 0 in spilled_rids, spilled_rids
        while bat.busy:
            bat.tick()
        out = {c.rid: c for c in bat.completed}
        for r in reqs:
            ref = _solo(eng, r.prompt, r.max_new_tokens)
            np.testing.assert_array_equal(out[r.rid].tokens, ref,
                                          err_msg=f"rid={r.rid}")

    def test_oversub_one_has_no_spill_traffic(self, mesh11, rng):
        """K=1 reproduces the slot-capped scheduler exactly: no spills, no
        slotless prefill."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        bat = ContinuousBatcher(eng)     # scfg.oversub defaults to 1.0
        bat.run(make_trace(5, vocab_size=cfg.vocab_size, seed=2))
        assert bat.oversub == 1.0
        assert not bat.spill_events
        assert bat.virtual_slots == bat.slots


class TestPrefixReuse:
    def test_cached_prefix_bit_identical_to_cold_path(self, mesh11, rng):
        """Acceptance pin: a repeated system prompt resolves to refill +
        tail chunk-prefill, and the greedy continuation is bit-identical
        to cold ``generate``."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        sys_prompt = np.asarray(
            jax.random.randint(rng, (8,), 0, cfg.vocab_size), np.int32)
        tails = [np.asarray(jax.random.randint(
            jax.random.fold_in(rng, i), (6 + i,), 0, cfg.vocab_size),
            np.int32) for i in range(3)]
        reqs = [Request(i, np.concatenate([sys_prompt, t]), 5)
                for i, t in enumerate(tails)]

        store = PrefixStore(8 << 20)
        cold = ContinuousBatcher(eng, prefix_store=store)
        cold.run([reqs[0]])
        assert len(store) > 0, "no snapshots taken during prefill"

        warm = ContinuousBatcher(eng, prefix_store=store)
        out = {c.rid: c for c in warm.run(reqs[1:])}
        assert warm.prefix_hits == 2
        assert warm.prefix_tokens_saved >= 2 * len(sys_prompt)
        for r in reqs[1:]:
            ref = _solo(eng, r.prompt, r.max_new_tokens)
            np.testing.assert_array_equal(out[r.rid].tokens, ref,
                                          err_msg=f"rid={r.rid}")

    def test_identical_prompt_rerun_hits_longest_prefix(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompt = np.asarray(jax.random.randint(rng, (20,), 0,
                                               cfg.vocab_size), np.int32)
        store = PrefixStore(8 << 20)
        ContinuousBatcher(eng, prefix_store=store).run(
            [Request(0, prompt, 4)])
        warm = ContinuousBatcher(eng, prefix_store=store)
        out = warm.run([Request(1, prompt, 4)])[0]
        # chunk budget 8 -> boundaries 8, 16: longest strict prefix is 16
        assert warm.prefix_tokens_saved == 16
        np.testing.assert_array_equal(out.tokens, _solo(eng, prompt, 4))

    def test_store_respects_byte_budget(self):
        store = PrefixStore(100)
        state = {"pos": np.zeros((1,), np.int32),
                 "k": np.zeros((10,), np.int8)}        # 14 bytes
        for i in range(20):
            store.put(np.arange(i + 1, dtype=np.int32), dict(state))
        assert store.bytes <= 100
        assert len(store) <= 100 // 14

    def test_lookup_never_returns_full_prompt(self):
        store = PrefixStore(1 << 20)
        toks = np.arange(8, dtype=np.int32)
        store.put(toks, {"pos": np.full((1,), 8, np.int32)})
        # the batcher caps max_len at len(prompt) - 1: an exact-length
        # snapshot must not swallow the whole prompt (no logits to seed
        # the first token)
        assert store.lookup(toks, max_len=len(toks) - 1) is None
        hit = store.lookup(np.concatenate([toks, [99]]), max_len=8)
        assert hit is not None and hit[0] == 8


class TestPlanMapping:
    """tier_kv_capacity x oversubscription interplay (PR 8 pins)."""

    def test_unbounded_and_two_tier_value_identical(self):
        from repro.serve.engine import tier_kv_oversub

        cfg = get_smoke_config("qwen3_14b")
        hbm = ipu_pod4_hbm()
        assert tier_kv_oversub(cfg, hbm, slots=4, cache_capacity=64) == 1.0
        assert tier_kv_oversub(cfg, hbm.with_stacked_dram(), slots=4,
                               cache_capacity=64) == 1.0
        assert tier_kv_oversub(cfg, None, slots=4, cache_capacity=64) == 1.0

    def test_finite_hierarchy_gets_k_above_one(self):
        from repro.serve.engine import _OVERSUB_MAX, tier_kv_oversub

        cfg = get_smoke_config("qwen3_14b")
        chip = ipu_mk2().with_stacked_dram(1 * GB)
        k = tier_kv_oversub(cfg, chip, slots=2, cache_capacity=64)
        assert 1.0 < k <= _OVERSUB_MAX

    def test_k_scales_with_ring_budget(self):
        from repro.serve.engine import kv_ring_bytes, tier_kv_oversub

        cfg = get_smoke_config("whisper_tiny")
        ring = kv_ring_bytes(cfg, 64)
        # room for exactly 6 rings beyond the (zero-spill) smoke weights
        chip = ipu_mk2().with_stacked_dram(6 * ring)
        k = tier_kv_oversub(cfg, chip, slots=2, cache_capacity=64)
        assert k == pytest.approx(3.0)

    def test_serve_config_exposes_plan_k(self):
        from repro.serve.engine import elk_serve_config

        cfg = get_smoke_config("qwen3_14b")
        chip = ipu_mk2().with_stacked_dram(1 * GB)
        sc = elk_serve_config(cfg, batch=2, cache_capacity=64, num_chips=1,
                              pod=chip)
        assert sc.oversub > 1.0
        assert sc.slot_spill_s > 0.0
        assert sc.prefix_cache_bytes > 0
        assert sc.virtual_slots >= 2 * sc.slots
        # hbm-backed pod: PR 8 values untouched
        sc2 = elk_serve_config(cfg, batch=2, cache_capacity=64,
                               num_chips=4, pod=ipu_pod4_hbm())
        assert sc2.oversub == 1.0
        assert sc2.slot_spill_s == 0.0
        assert sc2.prefix_cache_bytes == 0
        assert sc2.virtual_slots == sc2.slots


class TestTrafficAndTrace:
    def test_make_trace_back_compat_and_new_knobs(self):
        old = make_trace(6, vocab_size=100, arrival_spacing_s=0.5, seed=9)
        new = make_trace(6, vocab_size=100, arrival_spacing_s=0.5, seed=9,
                         burst=1, sys_prompt_frac=0.0)
        for a, b in zip(old, new):
            np.testing.assert_array_equal(a.prompt, b.prompt)
            assert a.arrival_s == b.arrival_s

        bursty = make_trace(6, vocab_size=100, arrival_spacing_s=0.5,
                            seed=9, burst=3)
        assert [r.arrival_s for r in bursty] == [0, 0, 0, 0.5, 0.5, 0.5]
        # same base randomness, grouped arrivals
        for a, b in zip(old, bursty):
            np.testing.assert_array_equal(a.prompt, b.prompt)

        shared = make_trace(8, vocab_size=100, seed=9, sys_prompt_len=8,
                            sys_prompt_frac=1.0)
        sys_prompt = shared[0].prompt[:8]
        for r in shared:
            np.testing.assert_array_equal(r.prompt[:8], sys_prompt)
        # deterministic across calls
        again = make_trace(8, vocab_size=100, seed=9, sys_prompt_len=8,
                           sys_prompt_frac=1.0)
        for a, b in zip(shared, again):
            np.testing.assert_array_equal(a.prompt, b.prompt)

    def test_summarize_reports_ttft(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        reqs = make_trace(4, vocab_size=cfg.vocab_size, seed=1)
        comps = ContinuousBatcher(eng).run(reqs)
        stats = summarize(comps, 1.0)
        assert "p50_ttft_s" in stats and "p99_ttft_s" in stats
        for c in comps:
            assert c.first_token_s >= 0
            assert 0 <= c.ttft_s <= c.latency_s + 1e-9
        static = run_static_trace(eng, reqs)
        sstats = summarize(static, 1.0)
        # lock-step only yields tokens at batch completion: TTFT == latency
        assert sstats["p50_ttft_s"] == sstats["p50_latency_s"]

    def test_spill_events_price_on_the_simulator(self, mesh11, rng):
        """Gate (c)'s property at test scale: the per-tier serial servers
        re-price the batcher's spill events within 2x of the planner (and
        exactly serialize same-tier transfers)."""
        from repro.chip.simulator import simulate_kv_traffic
        from repro.core.cost_model import AnalyticCostModel

        chip = ipu_mk2().with_stacked_dram(1 * GB)
        cm = AnalyticCostModel(chip)
        nb = 1 << 20
        one = cm.spill_time(nb, 0, chip.backing_tier)
        events = [("spill", nb), ("refill", nb), ("spill", nb)]
        res = simulate_kv_traffic(chip, events)
        assert res.total_time == pytest.approx(3 * one)
        assert res.finish == pytest.approx([one, 2 * one, 3 * one])
        planner = 3 * one
        assert 0.5 <= res.total_time / planner <= 2.0
        # 'at' release times create idle gaps the serial server respects
        res2 = simulate_kv_traffic(chip, [("spill", nb, 0.0),
                                          ("refill", nb, 10 * one)])
        assert res2.total_time == pytest.approx(11 * one)
