"""Tiered on-chip memory tests (DESIGN.md §10).

Covers the issue's acceptance criteria and satellites:

* two-tier bit-identity — a chip whose ``mem_tiers`` spec is passed
  explicitly (and canonicalized) plans bit-identically to the default
  scalar-field construction, across the model zoo and every shipped
  topology; the degenerate single-tier chip (``hbm_bw=0``) is pinned too;
* ``plan_signature`` folds ``mem_signature`` into every plan-cache key:
  toggling a stacked tier on and off can never serve a stale entry;
* ``place_tiers`` properties — never worse than the all-backing
  placement, byte conservation, capacity respected (fuzzed);
* ``IncrementalWindow`` replays a from-scratch §4.3 greedy exactly,
  per memory tier, as items stream in;
* the serve engine's tier-resident KV budget: unbounded (no clamp) for
  every hbm-backed chip, finite on an all-finite hierarchy;
* tier_kv_capacity x oversubscription (DESIGN.md §11): K > 1 only when
  every backing tier is finite; hbm-backed and two-tier configs keep the
  PR-8 ServeConfig values (K=1, no spill pricing, no prefix store);
* the DSE sweep: the stacked-DRAM design point strictly improves opt_30b
  decode with the simulator agreeing within 2x.
"""

import dataclasses
import random
import types

import pytest

from repro.chip.config import GB, TB, MemoryTier, ipu_mk2, ipu_pod4_hbm
from repro.chip.topology import TOPOLOGIES
from repro.configs import ARCH_IDS, PAPER_MODEL_IDS, get_config, \
    get_smoke_config
from repro.core.allocator import (IncrementalWindow, WindowItem, allocate,
                                  place_tiers)
from repro.core.cost_model import AnalyticCostModel
from repro.core.graph import build_graph
from repro.core.pipeline import (clear_plan_cache, compile_pipeline,
                                 plan_signature)
from repro.core.pipeline_pod import plan_pipeline

CHIP = ipu_pod4_hbm()


def tiny_cfg(num_layers: int = 2, **kw):
    return dataclasses.replace(get_config("opt_30b"),
                               num_layers=num_layers, **kw)


def smoke(model: str):
    if model in PAPER_MODEL_IDS:
        return dataclasses.replace(get_config(model), num_layers=2)
    return get_smoke_config(model)


def plans_equal(a, b) -> bool:
    """Bit-identical schedules: same timings, same per-op plan choices."""
    if a.total_time != b.total_time or a.preload_order != b.preload_order:
        return False
    for da, db in zip(a.decisions, b.decisions):
        if da.exec_plan.key() != db.exec_plan.key():
            return False
        if da.src_tier != db.src_tier:
            return False
        fa = da.preload_plan.frac if da.preload_plan else None
        fb = db.preload_plan.frac if db.preload_plan else None
        if fa != fb:
            return False
    return True


# ---------------------------------------------------------------------------
# two-tier bit-identity (acceptance: defaults reproduce current plans)
# ---------------------------------------------------------------------------

class TestTwoTierBitIdentity:
    def explicit(self, chip):
        """The same chip with its memory spec passed in explicitly;
        canonicalization must rebuild the identical hierarchy."""
        exp = chip.scaled(mem_tiers=chip.mem_tiers)
        assert exp == chip
        assert exp.mem_signature == chip.mem_signature
        return exp

    @pytest.mark.parametrize("model", ARCH_IDS + PAPER_MODEL_IDS)
    def test_models_bit_identical(self, model):
        cfg = smoke(model)
        exp = self.explicit(CHIP)
        a = compile_pipeline(cfg, CHIP, batch=2, seq=64, max_orders=2,
                             cache=False)
        b = compile_pipeline(cfg, exp, batch=2, seq=64, max_orders=2,
                             cache=False)
        assert plans_equal(a, b)
        # two-tier chips place every block in the backing store
        assert all(d.src_tier in (-1, CHIP.backing_tier)
                   for d in a.decisions)

    @pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
    def test_topologies_bit_identical(self, topo):
        cfg = tiny_cfg()
        chip = ipu_pod4_hbm(topology=topo)
        exp = self.explicit(chip)
        a = compile_pipeline(cfg, chip, batch=4, seq=128, max_orders=2,
                             cache=False)
        b = compile_pipeline(cfg, exp, batch=4, seq=128, max_orders=2,
                             cache=False)
        assert plans_equal(a, b)

    def test_two_tier_placement_all_backing(self):
        g = build_graph(tiny_cfg(), batch=4, seq=128, phase="decode")
        tp = place_tiers(CHIP, g.ops)
        assert CHIP.staging_tiers == ()
        assert set(tp.tier_of) <= {CHIP.backing_tier}
        assert all(s == 0 for s in tp.staged_bytes)
        assert tp.fill_time == 0.0

    def test_single_tier_hbm0_pin(self):
        """``hbm_bw=0`` degenerates to a one-tier (SRAM-only) hierarchy."""
        chip = ipu_mk2()
        assert [t.name for t in chip.mem_tiers] == ["sram"]
        assert chip.backing_tier == 0 and chip.staging_tiers == ()
        g = build_graph(smoke("whisper_tiny"), batch=2, seq=32,
                        phase="decode")
        tp = place_tiers(chip, g.ops)
        assert set(tp.tier_of) <= {0}
        assert tp.chains == (0.0,) and tp.fill_time == 0.0
        assert tp.bottleneck == tp.noc_chain
        exp = self.explicit(chip)
        cfg = smoke("whisper_tiny")
        a = compile_pipeline(cfg, chip, batch=2, seq=32, max_orders=2,
                             cache=False)
        b = compile_pipeline(cfg, exp, batch=2, seq=32, max_orders=2,
                             cache=False)
        assert plans_equal(a, b)


# ---------------------------------------------------------------------------
# plan_signature: tier toggling can never serve a stale cache entry
# ---------------------------------------------------------------------------

class TestPlanSignature:
    def test_mem_signature_joins_key(self):
        cfg = tiny_cfg()
        tiered = CHIP.with_stacked_dram()
        assert CHIP.mem_signature != tiered.mem_signature
        assert (plan_signature(cfg, CHIP, 4, 64)
                != plan_signature(cfg, tiered, 4, 64))

    def test_toggle_no_stale_hit(self):
        clear_plan_cache()
        cfg = tiny_cfg()
        tiered = CHIP.with_stacked_dram()
        a = compile_pipeline(cfg, CHIP, batch=4, seq=64, max_orders=2)
        b = compile_pipeline(cfg, tiered, batch=4, seq=64, max_orders=2)
        # retoggling hits each config's own entry, never the other's
        assert compile_pipeline(cfg, CHIP, batch=4, seq=64,
                                max_orders=2) is a
        assert compile_pipeline(cfg, tiered, batch=4, seq=64,
                                max_orders=2) is b
        clear_plan_cache()


# ---------------------------------------------------------------------------
# place_tiers properties (fuzzed)
# ---------------------------------------------------------------------------

def fake_ops(rng, n):
    return [types.SimpleNamespace(hbm_bytes=rng.randrange(0, 64 * 1024 * 1024))
            for _ in range(n)]


class TestPlaceTiers:
    def three_tier(self, capacity=64 * 1024 * 1024, bandwidth=8 * TB):
        return CHIP.with_stacked_dram(capacity, bandwidth)

    @pytest.mark.parametrize("seed", range(8))
    def test_never_worse_than_all_backing(self, seed):
        rng = random.Random(seed)
        chip = self.three_tier(capacity=rng.randrange(1, 256) * 1024 * 1024,
                               bandwidth=rng.choice([1, 4, 16]) * TB)
        ops = fake_ops(rng, rng.randrange(1, 24))
        cost = AnalyticCostModel(chip)
        tp = place_tiers(chip, ops, cost)
        backing = chip.backing_tier
        flat = max(tp.noc_chain,
                   sum(max(cost.tier_time(op.hbm_bytes, backing),
                           op.hbm_bytes / chip.preload_noc_bw)
                       for op in ops if op.hbm_bytes > 0))
        assert tp.bottleneck <= flat + 1e-12

    @pytest.mark.parametrize("seed", range(8))
    def test_conservation_and_capacity(self, seed):
        rng = random.Random(100 + seed)
        chip = self.three_tier(capacity=rng.randrange(1, 128) * 1024 * 1024)
        ops = fake_ops(rng, rng.randrange(1, 24))
        cost = AnalyticCostModel(chip)
        tp = place_tiers(chip, ops, cost)
        backing = chip.backing_tier
        # every block lands on a real tier; staged bytes tally exactly
        staged = [0] * len(chip.mem_tiers)
        for op, k in zip(ops, tp.tier_of):
            assert 0 < k <= backing or op.hbm_bytes == 0
            if 0 < k < backing:
                staged[k] += op.hbm_bytes
        assert list(tp.staged_bytes) == staged
        for k in chip.staging_tiers:
            assert staged[k] <= chip.mem_tiers[k].capacity
        # one-time refill is conserved: exactly the staged volume priced
        # by the cost model's spill roofline
        fill = sum(cost.spill_time(staged[k], backing, k)
                   for k in range(len(chip.mem_tiers)) if staged[k] > 0)
        assert tp.fill_time == pytest.approx(fill)
        assert (tp.fill_time > 0) == (sum(staged) > 0)


# ---------------------------------------------------------------------------
# IncrementalWindow == from-scratch greedy, per tier
# ---------------------------------------------------------------------------

def _naive_choices(chip, items, cap0):
    """Direct §4.3 greedy: per-tier, downgrade the best freed/added step
    until that store fits (first item wins ties).  Returns per-slot plan
    choices or None when some tier cannot fit."""
    choices = [(it.fixed_choice if it.fixed else 0) for it in items]
    for tier in sorted({it.tier for it in items}):
        mine = [i for i, it in enumerate(items) if it.tier == tier]
        cap = cap0 if tier <= 0 else chip.tier_capacity_per_core(tier)
        while sum(items[i].plans[choices[i]].space for i in mine) > cap:
            best = None
            for i in mine:
                it = items[i]
                if it.fixed or choices[i] + 1 >= len(it.plans):
                    continue
                cur, nxt = it.plans[choices[i]], it.plans[choices[i] + 1]
                freed = cur.space - nxt.space
                if freed <= 0:
                    continue
                added = (nxt.time - cur.time if it.role == "exec"
                         else nxt.dist_time - cur.dist_time)
                ratio = freed / max(added, 1e-12)
                if best is None or ratio > best[0]:
                    best = (ratio, i)
            if best is None:
                return None
            choices[best[1]] += 1
    return choices


def _fake_curve(rng, k):
    """A strict Pareto curve: space decreasing, time/dist_time increasing."""
    plans = []
    space = rng.randrange(64, 256) * 1024
    t = rng.random() * 1e-4
    for _ in range(k):
        plans.append(types.SimpleNamespace(
            space=space, time=t, dist_time=t * 0.5,
            noc_exec_bytes=space * 2))
        space -= rng.randrange(1, 32) * 1024
        t += rng.random() * 1e-5
    return plans


@pytest.mark.parametrize("seed", range(6))
def test_incremental_window_matches_scratch(seed):
    rng = random.Random(seed)
    chip = CHIP.with_stacked_dram(2 * GB)
    cap = 192 * 1024
    win = IncrementalWindow(chip, cap)
    items = []
    for i in range(rng.randrange(3, 9)):
        role = "exec" if i == 0 else "preload"
        fixed = role == "preload" and rng.random() < 0.3
        curve = _fake_curve(rng, rng.randrange(1, 7))
        items.append(WindowItem(i, role, curve, fixed=fixed,
                                fixed_choice=rng.randrange(len(curve))
                                if fixed else 0,
                                tier=rng.choice([0, 0, 1])))
        win.add_item(items[-1])
        # the warm trace after every add matches a cold solve of the
        # prefix AND the direct greedy
        inc = win.solve_core()
        cold = allocate(chip, items, capacity=cap)
        naive = _naive_choices(chip, items, cap)
        if naive is None:
            assert not inc[0] and not cold.feasible
        else:
            assert inc[0] and cold.feasible
            assert list(inc[1]) == naive
            assert [cold.choices[it.op_idx] for it in items] == naive


# ---------------------------------------------------------------------------
# serve engine: tier-resident KV budget
# ---------------------------------------------------------------------------

class TestServeKV:
    def test_unbounded_for_hbm_backed(self):
        from repro.serve.engine import tier_kv_capacity

        cfg = get_config("opt_30b")
        assert tier_kv_capacity(cfg, CHIP, batch=4) == 0
        assert tier_kv_capacity(cfg, CHIP.with_stacked_dram(), batch=4) == 0
        assert tier_kv_capacity(cfg, None, batch=4) == 0

    def test_finite_on_all_finite_hierarchy(self):
        from repro.serve.engine import tier_kv_capacity

        cfg = get_config("opt_30b")
        chip = ipu_mk2().with_stacked_dram(64 * GB)
        cap = tier_kv_capacity(cfg, chip, batch=4)
        assert cap > 0
        # budget scales with the tier and shrinks with the batch
        assert tier_kv_capacity(cfg, chip, batch=8) < cap
        big = ipu_mk2().with_stacked_dram(128 * GB)
        assert tier_kv_capacity(cfg, big, batch=4) > cap

    def test_serve_config_two_tier_value_identical(self):
        from repro.serve.engine import elk_serve_config

        sc = elk_serve_config(tiny_cfg(), batch=2, cache_capacity=128,
                              num_chips=4, pod=CHIP)
        assert sc.cache_capacity == 128

    def test_serve_config_clamps_to_tier_budget(self):
        from repro.serve.engine import elk_serve_config, tier_kv_capacity

        cfg = smoke("whisper_tiny")
        hd = cfg.resolved_head_dim
        per_token = cfg.num_layers * 2 * cfg.num_kv_heads * hd * 2
        chip = ipu_mk2().with_stacked_dram(64 * 2 * per_token)
        cap = tier_kv_capacity(cfg, chip, batch=2)
        assert cap == 64
        sc = elk_serve_config(cfg, batch=2, cache_capacity=256,
                              num_chips=1, pod=chip)
        assert sc.cache_capacity == 64


class TestServeKVOversub:
    """tier_kv_capacity x oversubscription (DESIGN.md §11): the admission
    multiplier K is funded by the same tier bytes the KV clamp reads, and
    every PR-8 config keeps the new ServeConfig fields at their no-op
    defaults."""

    def test_k_above_one_only_on_finite_hierarchy(self):
        from repro.serve.engine import _OVERSUB_MAX, tier_kv_oversub

        cfg = get_config("opt_30b")
        chip = ipu_mk2().with_stacked_dram(128 * GB)
        k = tier_kv_oversub(cfg, chip, slots=4, cache_capacity=2048)
        assert 1.0 < k <= _OVERSUB_MAX
        # fewer tier bytes can never fund more rings
        small = ipu_mk2().with_stacked_dram(80 * GB)
        assert tier_kv_oversub(cfg, small, slots=4,
                               cache_capacity=2048) <= k
        # unbounded-backed pods never oversubscribe: the resident cache
        # can simply grow, nothing forces a spill
        for pod in (CHIP, CHIP.with_stacked_dram(), None):
            assert tier_kv_oversub(cfg, pod, slots=4,
                                   cache_capacity=2048) == 1.0

    def test_exact_ring_arithmetic(self):
        from repro.serve.engine import kv_ring_bytes, tier_kv_oversub

        cfg = smoke("whisper_tiny")
        ring = kv_ring_bytes(cfg, 64)
        # room for exactly 10 rings beyond the (zero-spill) tiny weights
        chip = ipu_mk2().with_stacked_dram(10 * ring)
        assert tier_kv_oversub(cfg, chip, slots=2,
                               cache_capacity=64) == pytest.approx(5.0)

    def test_serve_config_unbounded_keeps_pr8_values(self):
        from repro.serve.engine import elk_serve_config

        sc = elk_serve_config(tiny_cfg(), batch=2, cache_capacity=128,
                              num_chips=4, pod=CHIP)
        assert (sc.oversub, sc.slot_spill_s, sc.prefix_cache_bytes) == \
            (1.0, 0.0, 0)
        assert sc.virtual_slots == sc.slots

    def test_serve_config_funds_k_and_prefix_store(self):
        from repro.serve.engine import elk_serve_config

        cfg = smoke("whisper_tiny")
        hd = cfg.resolved_head_dim
        per_token = cfg.num_layers * 2 * cfg.num_kv_heads * hd * 2
        # 1100 token-equivalents of tier bytes: capacity clamp stays above
        # the requested 256, four 256-token rings fit (K = 2 over batch=2)
        # and the 76-token remainder funds the prefix store
        chip = ipu_mk2().with_stacked_dram(1100 * per_token)
        sc = elk_serve_config(cfg, batch=2, cache_capacity=256,
                              num_chips=1, pod=chip)
        assert sc.cache_capacity == 256
        assert sc.oversub == pytest.approx(2.0)
        assert sc.virtual_slots == 4
        assert sc.slot_spill_s > 0.0
        assert sc.prefix_cache_bytes == 76 * per_token


# ---------------------------------------------------------------------------
# tiered pods: never worse, and the swept design point improves (acceptance)
# ---------------------------------------------------------------------------

class TestTieredPod:
    POD = ipu_pod4_hbm(topology="hier_pod")

    @pytest.mark.parametrize("size_gb,bw_tbps", [(4, 2), (8, 16)])
    def test_pipeline_never_worse(self, size_gb, bw_tbps):
        cfg = tiny_cfg(4)
        base = plan_pipeline(cfg, self.POD, batch=4, seq=256, max_orders=2)
        tiered = self.POD.with_stacked_dram(size_gb * GB, bw_tbps * TB)
        pp = plan_pipeline(cfg, tiered, batch=4, seq=256, max_orders=2)
        assert pp.batch_interval <= base.batch_interval + 1e-12

    def test_tier_sweep_improving_point(self):
        """The acceptance design point: stacked 8GB @ 16TB/s strictly
        improves planned opt_30b decode, with the event-driven simulator
        within 2x of the planner on every reported row."""
        from repro.chip.dse import tier_sweep

        rows = tier_sweep(sizes_gb=(8,), bws_tbps=(16,))
        base = [r for r in rows if r["tier"] == "none"]
        swept = [r for r in rows if r["tier"] != "none"]
        assert len(base) == 1 and swept
        assert all(r["speedup"] >= 1.0 - 1e-12 for r in swept)
        improved = [r for r in swept if r["improved"]]
        assert improved, "stacked 8GB@16TB/s must strictly improve"
        for r in improved:
            assert r["round_ms"] < base[0]["round_ms"]
            assert r["staged_mb"] > 0
        for r in base + improved:
            assert 0.5 <= r["plan_sim_ratio"] <= 2.0
