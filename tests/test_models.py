"""Per-arch smoke tests (assignment requirement) + model invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, PAPER_MODEL_IDS, get_config, \
    get_smoke_config
from repro.models import transformer as T
from repro.models.frontends import frontend_embeddings


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced same-family config: one forward + one train step on CPU,
    output shapes verified, no NaNs (the assignment's per-arch smoke)."""
    cfg = get_smoke_config(arch)
    params = T.init_params(rng, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(frontend_embeddings(cfg, B))

    logits = T.forward_train(params, cfg, tokens, remat=False,
                             **frontend_embeddings(cfg, B))
    exp_s = S + (cfg.vision_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch, remat=False))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert gn > 0, "gradients all zero"


@pytest.mark.parametrize("arch", ["qwen3_14b", "whisper_tiny"])
def test_forward_with_forced_fused_mlp(arch, rng):
    """The fused-MLP runtime path (interpret-mode Pallas, GLU and plain
    variants) must agree with the CPU einsum path at kernel tolerance —
    the model-level twin of the kernels/fused_mlp parity sweep, proving
    the §8 runtime wiring in models.layers.mlp changes backend, not
    semantics."""
    from repro.kernels.dispatch import force_kernels
    cfg = get_smoke_config(arch)
    params = T.init_params(rng, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    fe = frontend_embeddings(cfg, B)
    ref = T.forward_train(params, cfg, tokens, remat=False, **fe)
    with force_kernels():
        out = T.forward_train(params, cfg, tokens, remat=False, **fe)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-9
    assert err / scale < 2e-2, f"{arch}: fused path diverges ({err})"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    """prefill(S-1) + decode(1) last-token logits == forward(S) last-token
    logits — the serving-correctness invariant (MoE: dropless capacity)."""
    cfg = get_smoke_config(arch)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.moe_experts))
    params = T.init_params(rng, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    fe = frontend_embeddings(cfg, B)
    full = T.forward_train(params, cfg, tokens, remat=False, **fe)
    cache = T.init_cache(cfg, T.CacheSpec(capacity=S + 4, batch=B))
    _, cache = T.prefill(params, cfg, tokens[:, :S - 1], cache, **fe)
    ld, _ = T.decode_step(params, cfg, tokens[:, S - 1], cache)
    err = float(jnp.max(jnp.abs(full[:, -1].astype(jnp.float32)
                                - ld[:, 0].astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    assert err / scale < 0.02, f"{arch}: decode diverges from forward"


def test_swa_ring_buffer_eviction(rng):
    """Sliding-window arch with cache capacity == window: decoding past the
    window stays finite and attends only within the window."""
    cfg = get_smoke_config("h2o_danube_1_8b")   # window 16 after shrink
    params = T.init_params(rng, cfg)
    B = 2
    W = cfg.sliding_window
    cache = T.init_cache(cfg, T.CacheSpec(capacity=W, batch=B))
    toks = jax.random.randint(rng, (B, 3 * W), 0, cfg.vocab_size)
    _, cache = T.prefill(params, cfg, toks[:, :W], cache)
    for t in range(W, 3 * W):
        logits, cache = T.decode_step(params, cfg, toks[:, t], cache)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache["pos"]) == 3 * W


def test_unroll_scan_equivalence(rng):
    """unroll_scan (accounting variants) is numerically identical."""
    cfg = get_smoke_config("qwen3_14b")
    params = T.init_params(rng, cfg)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    l1 = T.loss_fn(params, cfg, batch, remat=False)
    cfg_u = dataclasses.replace(cfg, unroll_scan=True, attn_chunk=8)
    l2 = T.loss_fn(params, cfg_u, batch, remat=False)
    assert abs(float(l1) - float(l2)) < 5e-3


def test_int8_kv_cache_close_to_bf16(rng):
    cfg = get_smoke_config("qwen3_14b")
    params = T.init_params(rng, cfg)
    B, S = 2, 10
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    outs = {}
    for dt in (jnp.bfloat16, jnp.int8):
        cache = T.init_cache(cfg, T.CacheSpec(capacity=16, batch=B,
                                              kv_dtype=dt))
        _, cache = T.prefill(params, cfg, tokens[:, :S - 1], cache)
        ld, _ = T.decode_step(params, cfg, tokens[:, S - 1], cache)
        outs[str(dt)] = ld
    a = outs["<class 'jax.numpy.bfloat16'>"].astype(jnp.float32)
    b = outs["<class 'jax.numpy.int8'>"].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(a)))
                                            + 1e-9)
    assert rel < 0.15, f"int8 KV cache too lossy: {rel}"


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_MODEL_IDS)
def test_full_config_param_counts(arch):
    """Full configs instantiate structurally (eval_shape, no allocation)
    and parameter counts are in the advertised ballpark."""
    import math
    cfg = get_config(arch)
    tree = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(tree))
    expected = cfg.param_count()
    assert abs(total - expected) / expected < 0.25, (arch, total, expected)


EXPECTED_SCALE = {                 # sanity: advertised model scale
    "qwen1_5_32b": 32e9, "qwen3_14b": 14e9, "gemma_7b": 8.5e9,
    "h2o_danube_1_8b": 1.8e9, "internvl2_1b": 0.6e9,
    "llama4_maverick_400b_a17b": 400e9, "kimi_k2_1t_a32b": 1.0e12,
    "rwkv6_7b": 7e9, "whisper_tiny": 37e6, "hymba_1_5b": 1.5e9,
}


@pytest.mark.parametrize("arch", list(EXPECTED_SCALE))
def test_param_scale(arch):
    got = get_config(arch).param_count()
    want = EXPECTED_SCALE[arch]
    assert want / 2.5 < got < want * 2.5, (arch, got, want)
