"""Tests for the cached, incremental compile pipeline (DESIGN.md §1-§2).

Deliberately hypothesis-free: this file is the ELK-core coverage that still
runs where the optional dev dependencies are absent.
"""

import dataclasses
import math
import random

import pytest

from repro.chip.config import ipu_pod4_hbm
from repro.configs import get_config
from repro.core.allocator import (Allocation, IncrementalWindow, WindowItem,
                                  _window_cost, allocate)
from repro.core.elk import compare_designs, compile_model
from repro.core.graph import build_graph
from repro.core.partition import (enumerate_exec_plans,
                                  enumerate_preload_plans)
from repro.core.pipeline import (CompileContext, clear_plan_cache,
                                 plan_cache)
from repro.core.scheduler import Scheduler

CHIP = ipu_pod4_hbm()


@pytest.fixture(scope="module")
def small_cfg():
    return dataclasses.replace(get_config("llama2_13b"), num_layers=2)


@pytest.fixture(scope="module")
def small_graph(small_cfg):
    return build_graph(small_cfg, batch=32, seq=2048, phase="decode")


# ---------------------------------------------------------------------------
# incremental allocator == cold greedy
# ---------------------------------------------------------------------------

def _reference_allocate(chip, items, capacity=None, extra=0.0):
    """The pre-refactor §4.3 greedy, verbatim: the exactness oracle."""
    cap = capacity if capacity is not None else chip.usable_sram_per_core
    choice = {it.op_idx: (it.fixed_choice if it.fixed else 0) for it in items}
    space = sum(it.plans[choice[it.op_idx]].space for it in items)

    def steppable(it):
        return (not it.fixed) and choice[it.op_idx] + 1 < len(it.plans)

    while space > cap:
        best = None
        for it in items:
            if not steppable(it):
                continue
            j = choice[it.op_idx]
            cur, nxt = it.plans[j], it.plans[j + 1]
            freed = cur.space - nxt.space
            if freed <= 0:
                continue
            added = ((nxt.time - cur.time) if it.role == "exec"
                     else (nxt.dist_time - cur.dist_time))
            delta = freed / max(added, 1e-12)
            if best is None or delta > best[0]:
                best = (delta, it)
        if best is None:
            return Allocation(False, choice, math.inf, math.inf, math.inf,
                              space, math.inf)
        _, it = best
        old = it.plans[choice[it.op_idx]].space
        choice[it.op_idx] += 1
        space += it.plans[choice[it.op_idx]].space - old
    cost, e, d, nt = _window_cost(chip, items, choice, extra)
    return Allocation(True, choice, e, d, nt, space, cost)


class TestIncrementalAllocator:
    def _random_items(self, rng, graph, k):
        mats = [o for o in graph.ops if o.kind == "matmul"]
        ops = rng.sample(mats, k + 1)
        items = [WindowItem(0, "exec", enumerate_exec_plans(ops[0], CHIP))]
        for i, op in enumerate(ops[1:], 1):
            eps = enumerate_exec_plans(op, CHIP)
            ep = eps[rng.randrange(len(eps))]
            items.append(WindowItem(i, "preload",
                                    enumerate_preload_plans(op, ep, CHIP)))
        return items

    def test_matches_reference_on_random_windows(self, small_graph):
        rng = random.Random(7)
        for _ in range(60):
            items = self._random_items(rng, small_graph, rng.randint(1, 6))
            cap = int(CHIP.usable_sram_per_core * rng.uniform(0.05, 1.2))
            extra = rng.uniform(0.0, 1e9)
            got = allocate(CHIP, items, capacity=cap, extra_preload_noc=extra)
            want = _reference_allocate(CHIP, items, capacity=cap, extra=extra)
            assert got.feasible == want.feasible
            assert got.choices == want.choices
            assert got.space == want.space
            if got.feasible:
                assert got.cost == want.cost
                assert got.exec_time == want.exec_time
                assert got.noc_time == want.noc_time

    def test_incremental_grow_by_one_matches_scratch(self, small_graph):
        """The §4.2 backward induction's window families: solving after each
        add_item must equal a from-scratch allocate() of the same items."""
        rng = random.Random(11)
        for _ in range(25):
            items = self._random_items(rng, small_graph, rng.randint(2, 7))
            cap = int(CHIP.usable_sram_per_core * rng.uniform(0.1, 1.0))
            win = IncrementalWindow(CHIP, cap)
            for j, it in enumerate(items):
                win.add_item(it)
                inc = win.solve(0.0)
                scratch = _reference_allocate(CHIP, items[:j + 1],
                                              capacity=cap)
                assert inc.feasible == scratch.feasible, (j, cap)
                assert inc.choices == scratch.choices, (j, cap)
                assert inc.space == scratch.space

    def test_fixed_items_never_step(self, small_graph):
        op = next(o for o in small_graph.ops if o.kind == "matmul")
        plans = enumerate_exec_plans(op, CHIP)
        items = [WindowItem(0, "exec", plans, fixed=True, fixed_choice=0),
                 WindowItem(1, "preload",
                            enumerate_preload_plans(op, plans[0], CHIP))]
        a = allocate(CHIP, items, capacity=plans[0].space)
        assert a.choices[0] == 0


# ---------------------------------------------------------------------------
# curve / window caches
# ---------------------------------------------------------------------------

class TestCurveCache:
    def test_identical_layers_share_curves(self, small_graph):
        ctx = CompileContext(CHIP)
        l0 = [op for op in small_graph.ops if op.layer == 0]
        l1 = [op for op in small_graph.ops if op.layer == 1]
        for a, b in zip(l0, l1):
            assert ctx.curves.exec_plans(a) is ctx.curves.exec_plans(b)

    def test_hits_across_designs(self, small_cfg):
        """compare_designs shares one context: every design after the first
        reuses curves and allocation windows."""
        clear_plan_cache()
        ctx = CompileContext(CHIP)
        compare_designs(small_cfg, CHIP, batch=32, seq=2048, phase="decode",
                        ctx=ctx, cache=False)
        assert ctx.curves.hits > 0
        assert ctx.curves.hits > ctx.curves.misses
        assert ctx.windows.hits > 0

    def test_uid_registry(self, small_graph):
        ctx = CompileContext(CHIP)
        op = small_graph.ops[0]
        plans = ctx.curves.exec_plans(op)
        assert ctx.curves.uid_of(plans) is not None
        assert ctx.curves.uid_of([1, 2, 3]) is None


# ---------------------------------------------------------------------------
# pipeline plan identity + plan cache
# ---------------------------------------------------------------------------

class TestPlanIdentity:
    def test_warm_context_bit_identical_to_cold(self, small_cfg):
        """A plan from a warm shared context equals a cold compile exactly:
        same total_time, same decisions, same preload order, same timing."""
        clear_plan_cache()
        ctx = CompileContext(CHIP)
        # warm the context with other designs/orders first
        compile_model(small_cfg, CHIP, batch=32, seq=2048, phase="decode",
                      design="ELK-Dyn", ctx=ctx, cache=False)
        warm = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                             phase="decode", design="ELK-Full", ctx=ctx,
                             cache=False)
        cold = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                             phase="decode", design="ELK-Full", cache=False)
        assert warm.total_time == cold.total_time
        assert warm.preload_order == cold.preload_order
        assert warm.decisions == cold.decisions
        assert warm.timing == cold.timing

    def test_scheduler_private_ctx_matches_shared(self, small_graph):
        shared = CompileContext(CHIP)
        p1 = Scheduler(small_graph, CHIP, ctx=shared).schedule()
        p2 = Scheduler(small_graph, CHIP).schedule()
        assert p1.total_time == p2.total_time
        assert p1.decisions == p2.decisions

    def test_plan_cache_returns_same_object(self, small_cfg):
        clear_plan_cache()
        kw = dict(batch=32, seq=2048, phase="decode", design="Static")
        a = compile_model(small_cfg, CHIP, **kw)
        b = compile_model(small_cfg, CHIP, **kw)
        assert a is b
        assert plan_cache().hits > 0

    def test_plan_cache_distinguishes_designs(self, small_cfg):
        clear_plan_cache()
        a = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                          phase="decode", design="Basic")
        b = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                          phase="decode", design="Ideal")
        assert a.design == "Basic" and b.design == "Ideal"

    def test_parallel_orders_match_serial(self, small_cfg):
        clear_plan_cache()
        serial = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                               phase="decode", design="ELK-Full",
                               max_orders=6, cache=False)
        par = compile_model(small_cfg, CHIP, batch=32, seq=2048,
                            phase="decode", design="ELK-Full",
                            max_orders=6, cache=False, parallel=2)
        assert par.total_time == serial.total_time
        assert par.preload_order == serial.preload_order


# ---------------------------------------------------------------------------
# fusion knob vs the caches (DESIGN.md §8 regression: toggling fusion must
# never serve a stale entry, mirroring the topo_signature guarantees)
# ---------------------------------------------------------------------------

class TestFusionCacheKeys:
    KW = dict(batch=32, seq=2048, phase="decode", design="ELK-Full")

    def test_toggle_never_hits_stale_entry(self, small_cfg):
        """off -> on -> off -> on through the process plan cache: the two
        knob settings key separately, hit their own entries, and always
        return distinct plan objects (even when the fused graph loses the
        selection, the fusion-on result is a fresh replace())."""
        clear_plan_cache()
        off1 = compile_model(small_cfg, CHIP, **self.KW)
        on1 = compile_model(small_cfg, CHIP, fusion=True, **self.KW)
        off2 = compile_model(small_cfg, CHIP, **self.KW)
        on2 = compile_model(small_cfg, CHIP, fusion=True, **self.KW)
        assert off1 is off2 and on1 is on2      # each knob hits its entry
        assert off1 is not on1                  # never a cross-knob hit
        assert off1.fusion is False
        assert isinstance(on1.fusion, bool)

    def test_fusion_on_bit_identical_across_compiles(self, small_cfg):
        """Two cold fusion-on compiles (fresh contexts, no process cache)
        agree exactly — the fused curves, windows and selection are
        deterministic."""
        clear_plan_cache()
        a = compile_model(small_cfg, CHIP, cache=False, fusion=True,
                          ctx=CompileContext(CHIP), **self.KW)
        b = compile_model(small_cfg, CHIP, cache=False, fusion=True,
                          ctx=CompileContext(CHIP), **self.KW)
        assert a.total_time == b.total_time
        assert a.fusion == b.fusion
        assert a.preload_order == b.preload_order
        assert a.decisions == b.decisions

    def test_shared_context_not_polluted_by_fusion(self, small_cfg):
        """A fusion-on compile through a shared context must not perturb a
        later fusion-off compile: window keys carry the graph's fusion
        signature."""
        clear_plan_cache()
        cold = compile_model(small_cfg, CHIP, cache=False, **self.KW)
        ctx = CompileContext(CHIP)
        compile_model(small_cfg, CHIP, cache=False, fusion=True, ctx=ctx,
                      **self.KW)
        warm = compile_model(small_cfg, CHIP, cache=False, ctx=ctx,
                             **self.KW)
        assert warm.total_time == cold.total_time
        assert warm.decisions == cold.decisions
        assert warm.preload_order == cold.preload_order
