"""Pipeline-parallel pod planner tests (DESIGN.md §7).

Covers the issue's acceptance criteria and satellites:

* degenerate equivalence — one stage / one chip is bit-identical to the
  single-chip compile path, and ``hier_pod`` with ``num_chips=1`` matches
  the corresponding flat ``all2all`` chip (flow weights, delivery
  bandwidth, plans);
* conservation — ``PipelinePlan`` conserves total FLOPs and HBM bytes
  across arbitrary stage cuts (fuzzed);
* simulator agreement — ``simulate_pipeline`` within 2x of the planner's
  steady-state interval on every shipped topology;
* the 4-chip ``hier_pod`` pipeline beats replicating the single-chip
  ELK-Full plan per chip on opt_30b decode;
* ``pod_plan`` knob regressions: default flat knobs unchanged, the
  prefetch-depth clamp derived from capacity.
"""

import dataclasses

import pytest

from repro.chip.config import ChipConfig, ipu_pod4_hbm, tpu_v5e_pod
from repro.chip.simulator import simulate_pipeline
from repro.chip.topology import TOPOLOGIES, build_topology
from repro.configs import get_config
from repro.core.elk import compile_model
from repro.core.graph import build_graph
from repro.core.integration import pod_plan
from repro.core.pipeline_pod import (plan_pipeline, replicated_plan,
                                     stage_subgraph, steady_interval)

POD = ipu_pod4_hbm(topology="hier_pod")


def tiny_cfg(num_layers: int = 4, **kw):
    return dataclasses.replace(get_config("opt_30b"),
                               num_layers=num_layers, **kw)


def plans_equal(a, b) -> bool:
    """Bit-identical schedules: same timings, same per-op plan choices."""
    if a.total_time != b.total_time or a.preload_order != b.preload_order:
        return False
    for da, db in zip(a.decisions, b.decisions):
        if da.exec_plan.key() != db.exec_plan.key():
            return False
        fa = da.preload_plan.frac if da.preload_plan else None
        fb = db.preload_plan.frac if db.preload_plan else None
        if fa != fb:
            return False
    return True


# ---------------------------------------------------------------------------
# degenerate equivalence
# ---------------------------------------------------------------------------

class TestDegenerate:
    def test_single_stage_is_single_chip_plan(self):
        cfg = tiny_cfg()
        pp = plan_pipeline(cfg, POD, batch=8, seq=256, num_stages=1)
        ref = compile_model(cfg, POD, batch=8, seq=256, phase="decode",
                            design="ELK-Full", max_orders=4)
        assert pp.num_stages == 1 and pp.microbatches == 1
        assert plans_equal(pp.stages[0].plan, ref)
        assert pp.interval == ref.total_time
        assert pp.batch_interval == ref.total_time
        assert pp.total_time == ref.total_time

    def test_single_chip_pod_degenerates(self):
        cfg = tiny_cfg()
        pod1 = dataclasses.replace(
            POD, num_chips=1, num_cores=POD.cores_per_chip,
            hbm_bw=POD.hbm_bw / 4, hbm_controllers=4)
        pp = plan_pipeline(cfg, pod1, batch=8, seq=256)
        assert pp.num_stages == 1
        ref = compile_model(cfg, pod1, batch=8, seq=256, phase="decode",
                            design="ELK-Full", max_orders=4)
        assert plans_equal(pp.stages[0].plan, ref)

    def test_chip_view_identity_for_single_chip(self):
        chip = dataclasses.replace(POD, num_chips=1,
                                   num_cores=POD.cores_per_chip)
        view = chip.chip_view()
        assert view.chip is chip
        assert view.num_chips == 1


# ---------------------------------------------------------------------------
# hier_pod(num_chips=1) == flat all2all (satellite property test)
# ---------------------------------------------------------------------------

class TestHierPodDegeneratesToAll2All:
    def pair(self):
        base = dict(name="one-chip", num_cores=256, sram_per_core=256 * 1024,
                    core_flops=1e11, core_flops_vector=1e10,
                    sram_bw_per_core=2e9, link_bw=5e9, num_chips=1,
                    hbm_bw=1e12, hbm_controllers=4)
        hier = ChipConfig(topology="hier_pod", **base)
        flat = ChipConfig(topology="all2all", **base)
        return hier, flat

    def test_flow_weights_and_delivery(self):
        hier, flat = self.pair()
        th, tf = build_topology(hier), build_topology(flat)
        for kind in ("preload", "dist", "rot"):
            wh = {c: w for c, w in th.flow_weights(kind).items() if w > 0}
            wf = {c: w for c, w in tf.flow_weights(kind).items() if w > 0}
            assert wh == wf
        assert th.preload_delivery_bw == tf.preload_delivery_bw
        assert th.dist_latency == tf.dist_latency
        assert th.preload_latency == tf.preload_latency
        assert th.dist_time_factor == tf.dist_time_factor == 1.0
        assert th.rot_time_factor == tf.rot_time_factor == 1.0

    @pytest.mark.parametrize("batch,seq", [(1, 64), (4, 64), (1, 256),
                                           (4, 256)])
    def test_plans_identical(self, batch, seq):
        hier, flat = self.pair()
        cfg = tiny_cfg(2)
        ph = compile_model(cfg, hier, batch=batch, seq=seq, phase="decode",
                           design="ELK-Full", max_orders=2)
        pf = compile_model(cfg, flat, batch=batch, seq=seq, phase="decode",
                           design="ELK-Full", max_orders=2)
        assert plans_equal(ph, pf)


# ---------------------------------------------------------------------------
# conservation fuzz (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_layers,stages,batch", [
    (2, 1, 4), (2, 2, 8), (3, 2, 32), (4, 3, 8), (4, 4, 32), (5, 4, 4),
    (6, 3, 8), (7, 2, 32), (8, 4, 8)])
def test_pipeline_conserves_flops_and_hbm_bytes(num_layers, stages, batch):
    cfg = tiny_cfg(num_layers)
    stages = min(stages, num_layers, POD.num_chips)
    pp = plan_pipeline(cfg, POD, batch=batch, seq=256, num_stages=stages,
                       max_orders=2)
    g = build_graph(cfg, batch=pp.microbatch, seq=256, phase="decode")
    assert pp.total_flops == pytest.approx(sum(op.flops for op in g.ops))
    assert pp.hbm_bytes == sum(op.hbm_bytes for op in g.ops)
    # cuts tile the layer range without overlap
    spans = [st_.layers for st_ in pp.stages]
    assert spans[0][0] == 0 and spans[-1][1] == cfg.num_layers
    for (_, a), (b, _) in zip(spans, spans[1:]):
        assert a == b


def test_zero_cut_slack_widens_to_feasibility():
    """A zero-width band that admits no partition (L % S != 0) must widen
    instead of looping forever."""
    cfg = tiny_cfg(7)
    pp = plan_pipeline(cfg, POD, batch=8, seq=128, num_stages=4,
                       cut_slack=0, max_orders=2)
    assert pp.num_stages == 4
    assert pp.stages[-1].layers[1] == 7


def test_stage_subgraph_rebases_moe_preload_dep():
    cfg = dataclasses.replace(
        get_config("opt_30b"), num_layers=4, moe_experts=8, moe_top_k=2)
    g = build_graph(cfg, batch=4, seq=64, phase="decode")
    sub = stage_subgraph(g, 2, 4, 4)
    for i, op in enumerate(sub.ops):
        if op.preload_dep >= 0:
            assert 0 <= op.preload_dep < len(sub.ops)
            assert sub.ops[op.preload_dep].name.endswith("router")
            assert sub.ops[op.preload_dep].layer == op.layer


# ---------------------------------------------------------------------------
# simulator agreement (acceptance: within 2x on every shipped topology)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_sim_interval_within_2x(topo):
    cfg = tiny_cfg(8)
    chip = ipu_pod4_hbm(topology=topo)
    pp = plan_pipeline(cfg, chip, batch=32, seq=2048)
    sim = simulate_pipeline(pp, chip)
    ratio = sim.interval / pp.interval
    assert 0.5 <= ratio <= 2.0, (topo, ratio)


def test_sim_rejects_extrapolated_stages():
    # 80 layers over 2 stages: ~40-layer stage graphs exceed the exact-op
    # budget and extrapolate from truncations; simulating those would
    # misreport per-microbatch durations, so it must refuse
    cfg = get_config("llama2_70b")
    pod2 = dataclasses.replace(POD, num_chips=2)
    pp = plan_pipeline(cfg, pod2, batch=8, seq=256, num_stages=2,
                       cut_slack=2, max_orders=2)
    assert any(st_.plan.extrapolated_from_layers for st_ in pp.stages)
    with pytest.raises(ValueError, match="exact stage plans"):
        simulate_pipeline(pp, pod2)


# ---------------------------------------------------------------------------
# acceptance: 4-chip hier_pod pipeline beats per-chip replication (opt_30b)
# ---------------------------------------------------------------------------

def test_pipeline_beats_replicated_opt30b():
    cfg = get_config("opt_30b")
    pp = plan_pipeline(cfg, POD, batch=32, seq=2048)
    rep = replicated_plan(cfg, POD, batch=32, seq=2048)
    assert pp.num_stages == 4
    # same tokens per steady-state decode round on both sides: the pipeline
    # rotates 4 microbatches of 8 through the stages, the baseline serves 8
    # requests per chip with a full replica
    assert pp.batch_interval < rep.total_time
    # the steady interval never exceeds the per-pass latency
    for st_ in pp.stages:
        assert st_.interval <= st_.time + 1e-12


def test_steady_interval_bounds():
    cfg = tiny_cfg(4)
    member = POD.chip_view().chip
    plan = compile_model(cfg, member, batch=8, seq=256, phase="decode",
                         design="ELK-Full", max_orders=2)
    ival = steady_interval(plan, member)
    assert 0 < ival <= plan.total_time


# ---------------------------------------------------------------------------
# pod_plan knobs (satellite: capacity-derived clamp + regression pins)
# ---------------------------------------------------------------------------

class TestPodKnobs:
    def test_default_flat_knobs_unchanged(self):
        """Regression pin: the derived clamp keeps the pre-refactor knob
        outputs for the default pod config."""
        for model in ("llama2_13b", "qwen3_14b"):
            k = pod_plan(get_config(model), batch=8, seq=64, phase="decode")
            assert k.prefetch_depth == 3
            assert k.fsdp
            assert k.resident_fraction == pytest.approx(0.9738175675675675)
            assert k.num_stages == 1 and k.stage_boundaries == ()

    def test_clamp_derived_from_capacity(self):
        """The prefetch-depth clamp comes from how many layer-blocks fit
        in the prefetch share of the on-chip store: shrinking the store
        under the derived plan clamps the depth down to the one-block
        floor, without touching the plan itself."""
        from repro.core.integration import _plan_knobs

        cfg = get_config("llama2_13b")
        chip = tpu_v5e_pod(256)
        plan = compile_model(cfg, chip, batch=8, seq=64, phase="decode",
                             design="ELK-Full", max_orders=8)
        depth, _ = _plan_knobs(plan, chip)
        assert depth == 3                  # capacity ample: search decides
        lo, hi = plan.graph.layer_span
        per_layer = sum(op.hbm_bytes for op in plan.graph.ops[lo:hi])
        # store sized to half a block of prefetch budget -> floor of 1
        small = chip.scaled(sram_per_core=per_layer // chip.num_cores)
        assert _plan_knobs(plan, small)[0] == 1
        # store sized to exactly two blocks of prefetch budget -> cap 2
        mid = chip.scaled(sram_per_core=4 * per_layer // chip.num_cores)
        assert _plan_knobs(plan, mid)[0] == 2

    def test_pipeline_mode_returns_stage_knobs(self):
        cfg = tiny_cfg(8)
        k = pod_plan(cfg, batch=32, seq=2048, chip=POD, mode="pipeline")
        assert k.num_stages == 4
        assert len(k.stage_boundaries) == 4
        assert k.stage_boundaries[-1] == 8
        assert k.microbatch * k.microbatches >= 32
        assert k.interval_s > 0
        assert k.batch_interval_s == pytest.approx(
            k.microbatches * k.interval_s)

    def test_pod_plan_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="mode"):
            pod_plan(tiny_cfg(2), batch=4, seq=64, mode="ring")
