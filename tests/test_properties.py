"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.chip.config import ipu_pod4_hbm
from repro.configs import get_config
from repro.core.graph import build_graph
from repro.core.partition import (enumerate_exec_plans,
                                  enumerate_preload_plans)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.models.layers import softmax_xent
from repro.models.moe import capacity, moe_ffn, moe_params, router_weights
from repro.models.config import ModelConfig

CHIP = ipu_pod4_hbm()


@given(batch=st.sampled_from([1, 8, 32]),
       seq=st.sampled_from([128, 2048]),
       phase=st.sampled_from(["decode", "prefill"]))
@settings(max_examples=8, deadline=None)
def test_graph_flops_bytes_positive(batch, seq, phase):
    """Operator graphs are structurally sane for any (batch, seq, phase)."""
    g = build_graph(get_config("llama2_13b"), batch=batch, seq=seq,
                    phase=phase)
    assert len(g.ops) > 10
    for op in g.ops:
        assert op.flops > 0
        assert op.out_bytes > 0
        assert op.hbm_bytes >= 0
    # above-average ops dominate HBM traffic (paper §4.4: 289 of OPT-30B's
    # 2269 ops carry 99.8%); strict at the paper's shape, >=50% elsewhere
    heavy = [op for i, op in enumerate(g.ops) if g.hbm_heavy(i)]
    assert heavy
    share = sum(o.hbm_bytes for o in heavy) / sum(o.hbm_bytes
                                                  for o in g.ops)
    assert share > (0.8 if (batch, seq) == (32, 2048) else 0.5)


@given(st.data())
@settings(max_examples=12, deadline=None)
def test_preload_space_monotone_in_frac(data):
    """Smaller preload fraction => smaller space, larger dist time."""
    g = build_graph(get_config("llama2_13b"), batch=32, seq=2048,
                    phase="decode")
    mats = [o for o in g.ops if o.kind == "matmul" and o.hbm_bytes]
    op = data.draw(st.sampled_from(mats[:12]))
    ep = enumerate_exec_plans(op, CHIP)[0]
    pps = enumerate_preload_plans(op, ep, CHIP)
    fr = [p.frac for p in pps]
    assert fr == sorted(fr, reverse=True)
    sp = [p.space for p in pps]
    assert sp == sorted(sp, reverse=True)
    dt = [p.dist_time for p in pps]
    assert dt == sorted(dt)


@given(t=st.sampled_from([4, 16, 64]), e=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]), cf=st.floats(1.0, 4.0))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_bounds(t, e, k, cf):
    c = capacity(t, e, k, cf)
    assert c >= k
    assert c <= t * k + 1


@given(seed=st.integers(0, 2 ** 16), t=st.sampled_from([8, 32]))
@settings(max_examples=6, deadline=None)
def test_moe_combine_is_convex(seed, t):
    """Each output token is a convex combination of expert outputs: with
    all experts being the identity-ish same function, routed output stays
    bounded by input magnitude (no token double-counting in the scatter)."""
    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      moe_experts=4, moe_top_k=2)
    rng = jax.random.PRNGKey(seed)
    p = moe_params(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, 16),
                          jnp.float32)
    # dropless: every token fully routed
    out = moe_ffn(x, p, cfg, dropless=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    # gates sum to 1 per token
    gates, idx = router_weights(x @ p["router"], cfg.moe_top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_flash_attention_causality(seed):
    """Future-token perturbations never change past outputs."""
    rng = jax.random.PRNGKey(seed)
    b, h, s, d = 1, 2, 64, 16
    q = jax.random.normal(rng, (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, h, s, d))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, h, s, d))
    out1 = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                           interpret=True)
    k2 = k.at[:, :, s // 2:, :].set(9.0)
    v2 = v.at[:, :, s // 2:, :].set(-9.0)
    out2 = flash_attention(q, k2, v2, causal=True, bq=32, bk=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :s // 2]),
                               np.asarray(out2[:, :, :s // 2]), atol=1e-5)


@given(seed=st.integers(0, 2 ** 16), z=st.floats(0.0, 1e-3))
@settings(max_examples=10, deadline=None)
def test_xent_bounds(seed, z):
    """Cross entropy >= 0 and <= log V + z-term for any logits."""
    rng = jax.random.PRNGKey(seed)
    v = 32
    logits = jax.random.normal(rng, (2, 8, v), jnp.float32) * 3
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8), 0, v)
    loss = float(softmax_xent(logits, labels, z_loss=z))
    assert loss >= -1e-5


@given(vol=st.integers(1, 2 ** 30), hops=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_cost_model_monotone(vol, hops):
    from repro.core.cost_model import AnalyticCostModel
    cm = AnalyticCostModel(CHIP)
    assert cm.link_time(vol, hops=hops) >= cm.link_time(vol // 2, hops=hops)
    assert cm.hbm_time(vol) >= cm.hbm_time(vol // 2)


@given(m=st.integers(128, 8192), n=st.integers(128, 8192),
       k=st.integers(128, 8192))
@settings(max_examples=15, deadline=None)
def test_vmem_plan_always_fits(m, n, k):
    from repro.core.integration import vmem_plan
    p = vmem_plan(m, n, k)
    assert p.vmem_bytes <= int(128 * 1024 * 1024 * 0.75)
    assert p.bm >= 128 and p.bn >= 128 and p.bk >= 128
