"""Distributed-runtime tests: trainer fault tolerance, checkpoint
round-trip/resharding, serving engine equivalence, compression,
simulator/emulator sanity."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import compression as comp
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(wd, mesh, steps=20, ckpt_every=5, failure_hook=None,
                **tkw):
    cfg = get_smoke_config("qwen3_14b")
    return Trainer(
        cfg, DataConfig(batch=4, seq=16, vocab_size=cfg.vocab_size),
        AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=60),
        TrainerConfig(workdir=str(wd), total_steps=steps,
                      ckpt_every=ckpt_every, **tkw),
        mesh, failure_hook=failure_hook)


class TestTrainer:
    def test_loss_decreases(self, tmp_path, mesh11):
        t = _mk_trainer(tmp_path, mesh11, steps=25)
        log = t.run()
        assert log[-1]["loss"] < log[0]["loss"]

    def test_crash_restart_resumes(self, tmp_path, mesh11):
        class Crash(RuntimeError):
            pass

        def bomb(step):
            if step == 7:
                raise Crash()

        t = _mk_trainer(tmp_path, mesh11, steps=20, ckpt_every=3,
                        failure_hook=bomb)
        with pytest.raises(Crash):
            t.run()
        t.store.wait()
        # a fresh trainer resumes from the last checkpoint (step 6)
        t2 = _mk_trainer(tmp_path, mesh11, steps=20, ckpt_every=3)
        assert t2.step == 6
        t2.run()
        assert t2.step == 20

    def test_restart_is_deterministic(self, tmp_path, mesh11):
        """Resumed run reproduces the uninterrupted run exactly (the
        deterministic data pipeline + checkpointed state)."""
        ta = _mk_trainer(tmp_path / "a", mesh11, steps=12, ckpt_every=6)
        log_a = ta.run()

        tb = _mk_trainer(tmp_path / "b", mesh11, steps=6, ckpt_every=6)
        tb.run()
        tb.store.wait()
        tb2 = _mk_trainer(tmp_path / "b", mesh11, steps=12, ckpt_every=6)
        assert tb2.step == 6
        log_b = tb2.run()
        assert log_a[-1]["loss"] == pytest.approx(log_b[-1]["loss"],
                                                  rel=1e-5)

    def test_heartbeat_written(self, tmp_path, mesh11):
        t = _mk_trainer(tmp_path, mesh11, steps=6)
        t.run()
        import json
        hb = json.load(open(os.path.join(str(tmp_path), "heartbeat.json")))
        assert hb["step"] == 6

    def test_straggler_hook_fires(self, tmp_path, mesh11):
        import time
        t = _mk_trainer(tmp_path, mesh11, steps=14,
                        straggler_factor=1e-9, straggler_patience=2)
        t.run()
        assert t.straggler_events >= 1

    def test_grad_accum_matches_full_batch(self, mesh11):
        """ga=2 over batch B == ga=1 over batch B (same update direction)."""
        cfg = get_smoke_config("qwen3_14b")
        from repro.train.step import jit_train_step
        data = SyntheticLM(DataConfig(batch=8, seq=16,
                                      vocab_size=cfg.vocab_size))
        batch = data.batch_at(0)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        params_np = jax.tree.map(np.asarray, params)   # survives donation
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        outs = {}
        for ga in (1, 2):
            f, sh = jit_train_step(cfg, mesh11, ocfg, params, batch,
                                   grad_accum=ga)
            fresh = jax.tree.map(jnp.asarray, params_np)
            p = jax.device_put(fresh, sh["params"])
            s = jax.device_put(adamw.init_state(fresh, ocfg), sh["opt"])
            p2, s2, m, _ = f(p, s, batch, None)
            outs[ga] = (float(m["loss"]), p2)
        assert outs[1][0] == pytest.approx(outs[2][0], rel=3e-2)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            outs[1][1], outs[2][1])
        assert max(jax.tree.leaves(d)) < 1e-1


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        store.save(3, tree)
        store.save(7, tree)
        store.save(9, tree)
        assert store.steps() == [7, 9]      # keep=2 garbage-collects
        out = store.restore(9, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))

    def test_restore_with_resharding(self, tmp_path, mesh11):
        """Checkpoint written unsharded restores onto a mesh (elastic)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        store = CheckpointStore(str(tmp_path))
        tree = {"w": jnp.ones((8, 4))}
        store.save(1, tree)
        sh = {"w": NamedSharding(mesh11, P("data", None))}
        out = store.restore(1, tree, sh)
        assert out["w"].sharding == sh["w"]

    def test_crash_during_write_keeps_previous(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        tree = {"a": jnp.zeros((2,))}
        store.save(1, tree)
        # simulate a torn write: stale tmp dir must not count as a ckpt
        os.makedirs(os.path.join(str(tmp_path), "step_000000002.tmp"))
        assert store.latest_step() == 1


class TestServe:
    # one arch per cache family: dense GQA, RWKV state, hybrid-SSM, MoE
    @pytest.mark.parametrize("arch", ["qwen3_14b", "rwkv6_7b", "hymba_1_5b",
                                      "llama4_maverick_400b_a17b"])
    def test_stream_equals_gspmd(self, arch, mesh11, rng):
        cfg = get_smoke_config(arch)
        params = T.init_params(rng, cfg)
        prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
        outs = {}
        for mode in ("gspmd", "elk_stream"):
            eng = ServeEngine(cfg, mesh11, params, ServeConfig(
                batch=2, cache_capacity=32, mode=mode, prefetch_depth=2))
            outs[mode] = np.asarray(eng.generate(prompts, steps=5))
        np.testing.assert_array_equal(outs["gspmd"], outs["elk_stream"])

    def test_generate_token_count_edge_steps(self, mesh11, rng):
        """generate must return exactly S0 + steps tokens, including the
        steps=0 (no continuation) and steps=1 (prefill token only) edges."""
        cfg = get_smoke_config("qwen3_14b")
        params = T.init_params(rng, cfg)
        eng = ServeEngine(cfg, mesh11, params, ServeConfig(
            batch=2, cache_capacity=32))
        prompts = jax.random.randint(rng, (2, 7), 0, cfg.vocab_size)
        for steps in (0, 1, 3):
            out = np.asarray(eng.generate(prompts, steps=steps))
            assert out.shape == (2, 7 + steps)
            np.testing.assert_array_equal(out[:, :7], np.asarray(prompts))

    def test_prefetch_depth_invariance(self, mesh11, rng):
        """The ELK preload number changes scheduling, never results."""
        cfg = get_smoke_config("qwen3_14b")
        params = T.init_params(rng, cfg)
        prompts = jax.random.randint(rng, (2, 6), 0, cfg.vocab_size)
        ref = None
        for p in (1, 2, 4):
            eng = ServeEngine(cfg, mesh11, params, ServeConfig(
                batch=2, cache_capacity=16, mode="elk_stream",
                prefetch_depth=p))
            out = np.asarray(eng.generate(prompts, steps=4))
            if ref is None:
                ref = out
            np.testing.assert_array_equal(ref, out)


class TestCompression:
    def test_bf16_roundtrip_close(self):
        g = {"w": jnp.linspace(-1, 1, 128, dtype=jnp.float32)}
        wire, _ = comp.compress_grads(g, None, "bf16")
        assert wire["w"].dtype == jnp.bfloat16
        assert float(jnp.max(jnp.abs(
            wire["w"].astype(jnp.float32) - g["w"]))) < 1e-2

    def test_int8_error_feedback_telescopes(self):
        """Repeated identical grads: error feedback makes the running mean
        of the decoded stream converge to the true gradient."""
        g = {"w": jnp.array([0.301, -0.007, 0.513, 0.002], jnp.float32)}
        err = comp.init_error_feedback(g, "int8")
        acc = jnp.zeros(4)
        n = 50
        for _ in range(n):
            wire, err = comp.compress_grads(g, err, "int8")
            acc = acc + wire["w"]
        np.testing.assert_allclose(np.asarray(acc / n),
                                   np.asarray(g["w"]), atol=1e-3)


class TestSimAndEmu:
    def test_simulator_agrees_with_scheduler(self):
        """Event simulator total within 2x of the analytic plan estimate
        and never better than Ideal (independent model cross-check)."""
        from repro.chip.config import ipu_pod4_hbm
        from repro.chip.simulator import simulate
        from repro.core.elk import compile_model
        from repro.core.baselines import ideal_plan
        from repro.core.graph import build_graph
        cfg = dataclasses.replace(get_config("llama2_13b"), num_layers=2)
        chip = ipu_pod4_hbm()
        plan = compile_model(cfg, chip, batch=32, seq=2048, phase="decode",
                             design="ELK-Dyn")
        res = simulate(plan, chip)
        assert 0.4 * plan.total_time <= res.total_time <= 2.5 * plan.total_time
        ideal = ideal_plan(build_graph(cfg, batch=32, seq=2048,
                                       phase="decode"), chip)
        # the simulator overlaps transfers the Ideal roofline serializes
        # (per-op hbm_time latencies), so allow it to land slightly under
        assert res.total_time >= ideal.total_time * 0.6

    def test_emulator_validates_plans(self):
        from repro.chip.config import ipu_mk2
        from repro.chip.emulator import check_plan_numerics
        from repro.core.graph import build_graph
        from repro.core.partition import (enumerate_exec_plans,
                                          enumerate_preload_plans)
        cfg = get_config("llama2_13b")
        g = build_graph(cfg, batch=4, seq=128, phase="decode")
        op = next(o for o in g.ops if o.kind == "matmul")
        chip = ipu_mk2()
        plans = enumerate_exec_plans(op, chip)[:4]
        for ep in plans:
            pps = enumerate_preload_plans(op, ep, chip)
            for pp in (pps[0], pps[-1]):
                check_plan_numerics(ep, pp)
