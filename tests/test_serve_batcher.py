"""Continuous-batching serve path: donated (copy-free) cache steps, the
slot-addressable cache ops, chunked prefill, and the request scheduler's
greedy parity with lock-step serving."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve.batcher import (ContinuousBatcher, Request, make_trace,
                                 run_static_trace, summarize)
from repro.serve.engine import ServeConfig, ServeEngine


def _engine(mesh, cfg, rng, **kw):
    params = T.init_params(rng, cfg)
    scfg = ServeConfig(**{"batch": 2, "cache_capacity": 64,
                          "prefill_chunk": 8, **kw})
    return ServeEngine(cfg, mesh, params, scfg)


class TestDonation:
    def test_decode_step_is_copy_free(self, mesh11, rng):
        """The compiled decode step must alias the cache input to the cache
        output: no donation warnings, the input buffer is consumed, and on
        a single device the output reuses the very same buffer."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
        logits, cache = eng.prefill(prompts)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        jax.block_until_ready(cache)
        k_ptr = cache["k"].unsafe_buffer_pointer()

        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            logits, cache2 = eng.decode(tok, cache)
            jax.block_until_ready(cache2)
        donation_warnings = [w for w in caught
                             if "donat" in str(w.message).lower()]
        assert not donation_warnings, donation_warnings
        assert cache["k"].is_deleted()          # input was consumed...
        assert cache2["k"].unsafe_buffer_pointer() == k_ptr   # ...in place

        hlo = eng._decode.lower(eng.params, tok, cache2).compile().as_text()
        assert "input_output_alias" in hlo

    def test_slot_step_is_copy_free(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        eng._ensure_slots()
        jax.block_until_ready(eng.slot_cache)
        k_ptr = eng.slot_cache["k"].unsafe_buffer_pointer()
        eng.step(jnp.zeros((2,), jnp.int32))
        jax.block_until_ready(eng.slot_cache)
        assert eng.slot_cache["k"].unsafe_buffer_pointer() == k_ptr


class TestSlotCacheOps:
    def test_chunked_prefill_invariant_to_chunking(self, mesh11, rng):
        """Any chunking of the prompt yields the same next token and the
        same ring contents as a single-chunk pass."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        prompt = jax.random.randint(rng, (1, 13), 0, cfg.vocab_size)
        outs = {}
        for chunks in ((13,), (8, 4, 1), (4, 4, 4, 1)):
            rc = eng.new_request_cache()
            off = 0
            for t in chunks:
                tok, rc = eng.prefill_chunk(rc, prompt[:, off:off + t])
                off += t
            outs[chunks] = (int(tok[0]), jax.tree.map(np.asarray, rc))
        ref_tok, ref_cache = outs[(13,)]
        for chunks, (tok, cache) in outs.items():
            assert tok == ref_tok, chunks
            for key in ("k", "v", "pos", "slot_pos"):
                np.testing.assert_array_equal(cache[key], ref_cache[key],
                                              err_msg=f"{chunks}/{key}")

    def test_insert_evict_isolation(self, mesh11, rng):
        """Inserting/evicting one slot never perturbs the other slot's
        decode stream."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        pa = jax.random.randint(rng, (1, 9), 0, cfg.vocab_size)
        pb = jax.random.randint(jax.random.fold_in(rng, 1), (1, 5), 0,
                                cfg.vocab_size)

        def solo(prompt, steps):
            ref = np.asarray(eng.generate(jnp.tile(prompt, (2, 1)),
                                          steps=steps))
            return ref[0, prompt.shape[1]:]

        ref_a = solo(pa, 6)
        # slot 0 runs request A; request B joins at slot 1 mid-decode and
        # leaves before A finishes
        tok_a, rc = eng.prefill_chunk(eng.new_request_cache(), pa)
        eng.insert_slot(0, rc)
        toks = jnp.zeros((2,), jnp.int32).at[0].set(tok_a[0])
        got_a = [int(tok_a[0])]
        for i in range(5):
            if i == 1:
                tok_b, rcb = eng.prefill_chunk(eng.new_request_cache(), pb)
                eng.insert_slot(1, rcb)
                toks = toks.at[1].set(tok_b[0])
            if i == 3:
                eng.evict_slot(1)
            toks = eng.step(toks)
            got_a.append(int(toks[0]))
        np.testing.assert_array_equal(np.asarray(got_a, np.int32), ref_a)

    def test_slot_pos_wraparound(self, mesh11, rng):
        """cache_capacity < prompt_len + steps: the ring tags must hold
        exactly the last C positions, in both cache layouts."""
        cfg = get_smoke_config("qwen3_14b")
        cap, s0, steps = 16, 12, 10
        eng = _engine(mesh11, cfg, rng, cache_capacity=cap)
        prompt = jax.random.randint(rng, (2, s0), 0, cfg.vocab_size)

        # lock-step layout: shared (C,) tags
        logits, cache = eng.prefill(prompt)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        for _ in range(steps):
            logits, cache = eng.decode(tok, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        end = s0 + steps
        np.testing.assert_array_equal(
            np.sort(np.asarray(cache["slot_pos"])),
            np.arange(end - cap, end))

        # per-slot layout: (B,C) tags, one row per request
        tokc, rc = eng.prefill_chunk(eng.new_request_cache(), prompt[:1])
        eng.insert_slot(0, rc)
        cur = jnp.zeros((2,), jnp.int32).at[0].set(tokc[0])
        for _ in range(steps):
            cur = eng.step(cur)
        sp = np.asarray(eng.slot_cache["slot_pos"])
        np.testing.assert_array_equal(np.sort(sp[0]),
                                      np.arange(end - cap, end))
        # eviction masks the whole row again (stale K/V unreachable)
        eng.evict_slot(0)
        sp = np.asarray(eng.slot_cache["slot_pos"])
        assert (sp[0] == T._POS_SENTINEL).all()
        assert int(np.asarray(eng.slot_cache["pos"])[0]) == 0

    def test_wraparound_stream_equals_gspmd(self, mesh11, rng):
        """elk_stream and gspmd agree under ring-buffer wraparound too."""
        cfg = get_smoke_config("h2o_danube_1_8b")   # SWA family
        params = T.init_params(rng, cfg)
        prompts = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
        outs = {}
        for mode in ("gspmd", "elk_stream"):
            eng = ServeEngine(cfg, mesh11, params, ServeConfig(
                batch=2, cache_capacity=16, mode=mode))
            outs[mode] = np.asarray(eng.generate(prompts, steps=10))
        np.testing.assert_array_equal(outs["gspmd"], outs["elk_stream"])


class TestContinuousBatching:
    @pytest.mark.parametrize("mode", ["gspmd", "elk_stream"])
    def test_greedy_parity_and_out_of_order_completion(self, mode, mesh11,
                                                       rng):
        """Mixed-length trace through the scheduler: requests complete out
        of arrival order, and every request's greedy continuation is
        bit-identical to (a) serving it alone and (b) the lock-step
        ``generate`` path."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng, mode=mode, batch=2,
                      cache_capacity=64, prefill_chunk=8)
        lens = [(9, 8), (5, 2), (13, 5), (4, 9), (7, 1), (6, 3)]
        reqs = [Request(rid=i,
                        prompt=np.asarray(jax.random.randint(
                            jax.random.fold_in(rng, i), (s0,), 0,
                            cfg.vocab_size), np.int32),
                        max_new_tokens=new)
                for i, (s0, new) in enumerate(lens)]
        completions = ContinuousBatcher(eng).run(reqs)

        assert sorted(c.rid for c in completions) == list(range(len(reqs)))
        finish = [c.rid for c in completions]
        assert finish != sorted(finish), finish   # out of arrival order

        by_rid = {c.rid: c for c in completions}
        for r in reqs:
            got = by_rid[r.rid].tokens
            assert got.shape == (len(r.prompt) + r.max_new_tokens,)
            alone = ContinuousBatcher(eng).run(
                [Request(r.rid, r.prompt, r.max_new_tokens)])[0]
            np.testing.assert_array_equal(got, alone.tokens)
            ref = np.asarray(eng.generate(
                jnp.tile(jnp.asarray(r.prompt)[None, :], (2, 1)),
                steps=r.max_new_tokens))[0]
            np.testing.assert_array_equal(got, ref)

    # slot path exercises every cache family: RWKV state recurrence,
    # hybrid attention+SSM state, MoE dropless routing
    @pytest.mark.parametrize("arch", ["rwkv6_7b", "hymba_1_5b",
                                      "llama4_maverick_400b_a17b"])
    def test_slot_path_parity_across_families(self, arch, mesh11, rng):
        cfg = get_smoke_config(arch)
        eng = _engine(mesh11, cfg, rng, prefill_chunk=8)
        prompt = np.asarray(jax.random.randint(rng, (11,), 0,
                                               cfg.vocab_size), np.int32)
        got = ContinuousBatcher(eng).run([Request(0, prompt, 5)])[0].tokens
        ref = np.asarray(eng.generate(
            jnp.tile(jnp.asarray(prompt)[None, :], (2, 1)), steps=5))[0]
        np.testing.assert_array_equal(got, ref)

    def test_chunk_budget_clamped_to_capacity(self, mesh11, rng):
        """A prompt longer than the cache must prefill in sub-capacity
        chunks (ring wraps *between* chunks, never inside one)."""
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng, cache_capacity=16,
                      prefill_chunk=32)
        reqs = [Request(0, np.arange(2, 26, dtype=np.int32) % 9, 4)]
        out = ContinuousBatcher(eng).run(reqs)[0]
        assert out.tokens.shape == (28,)

    def test_empty_prompt_rejected(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        with pytest.raises(ValueError, match="empty prompt"):
            ContinuousBatcher(eng).submit(
                Request(0, np.zeros((0,), np.int32), 4))

    def test_zero_and_one_token_requests(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        reqs = [Request(0, np.arange(5, dtype=np.int32), 0),
                Request(1, np.arange(6, dtype=np.int32), 1),
                Request(2, np.arange(4, dtype=np.int32), 3)]
        out = {c.rid: c for c in ContinuousBatcher(eng).run(reqs)}
        np.testing.assert_array_equal(out[0].tokens, reqs[0].prompt)
        assert out[1].tokens.shape == (7,)
        assert out[2].tokens.shape == (7,)
        ref = np.asarray(eng.generate(
            jnp.tile(jnp.asarray(reqs[1].prompt)[None, :], (2, 1)),
            steps=1))[0]
        np.testing.assert_array_equal(out[1].tokens, ref)

    def test_int8_kv_slot_path_runs(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng, kv_dtype="int8")
        reqs = [Request(0, np.arange(6, dtype=np.int32), 4),
                Request(1, np.arange(9, dtype=np.int32), 2)]
        out = ContinuousBatcher(eng).run(reqs)
        assert sorted(c.rid for c in out) == [0, 1]
        for c in out:
            assert c.tokens.shape == (c.prompt_len + (4 if c.rid == 0
                                                      else 2),)

    def test_static_trace_baseline_accounts_all_requests(self, mesh11, rng):
        cfg = get_smoke_config("qwen3_14b")
        eng = _engine(mesh11, cfg, rng)
        trace = make_trace(5, vocab_size=cfg.vocab_size,
                           prompt_lens=(6, 9), max_new=(2, 4))
        out = run_static_trace(eng, trace)
        assert sorted(c.rid for c in out) == list(range(5))
        stats = summarize(out, 1.0)
        assert stats["requests"] == 5
        assert stats["gen_tok_s"] == pytest.approx(
            sum(r.max_new_tokens for r in trace))
