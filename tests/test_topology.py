"""Tests for the pluggable interconnect topology subsystem (DESIGN.md §5).

Covers: per-topology hop-count/bisection invariants, numeric back-compat of
the all2all/mesh2d scalar vocabulary, the prime-core-count mesh fallback,
simulator flow conservation and latency charging, torus-vs-mesh
monotonicity, topology-keyed pipeline cache misses, and topology-aware
compiler decisions.
"""

import dataclasses
import warnings

import pytest

from repro.chip.config import ChipConfig, KB, ipu_pod4_hbm
from repro.chip.simulator import simulate
from repro.chip.topology import TOPOLOGIES, near_square_grid
from repro.configs import get_config
from repro.core.baselines import build_plan
from repro.core.cost_model import AnalyticCostModel
from repro.core.elk import compile_model
from repro.core.graph import build_graph
from repro.core.pipeline import clear_plan_cache, plan_cache

ALL_TOPOLOGIES = ("all2all", "mesh2d", "torus2d", "ring", "hier_pod")


def chip_for(topo: str) -> ChipConfig:
    return ipu_pod4_hbm(topology=topo)


@pytest.fixture(scope="module")
def small_cfg():
    return dataclasses.replace(get_config("llama2_13b"), num_layers=2)


@pytest.fixture(scope="module")
def small_graph(small_cfg):
    return build_graph(small_cfg, batch=32, seq=2048, phase="decode")


# ---------------------------------------------------------------------------
# per-topology invariants
# ---------------------------------------------------------------------------

class TestTopologyInvariants:
    @pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
    def test_registry_and_basic_invariants(self, topo):
        chip = chip_for(topo)
        t = chip.topo
        assert TOPOLOGIES[topo] is type(t)
        assert t.preload_hops >= 1.0
        assert t.total_capacity > 0
        assert t.bisection_bw > 0
        assert t.preload_delivery_bw <= t.total_capacity + 1e-6
        names = {lc.name for lc in t.classes}
        for kind in ("preload", "dist", "rot"):
            assert set(t.flow_weights(kind)) <= names
        # occupancy is bottleneck-based and scales linearly
        occ = t.occupancy(1e9, 1e9, 1e9)
        assert occ > 0
        assert t.occupancy(2e9, 2e9, 2e9) == pytest.approx(2 * occ)

    @pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
    def test_signatures_distinct_and_stable(self, topo):
        chip = chip_for(topo)
        assert chip.topo_signature == chip_for(topo).topo_signature
        others = [chip_for(o).topo_signature for o in ALL_TOPOLOGIES
                  if o != topo]
        assert chip.topo_signature not in others

    def test_all2all_mesh2d_backcompat_constants(self):
        """The seed model's scalar hop-weight vocabulary, bit-for-bit."""
        a = chip_for("all2all")
        assert a.noc_capacity == a.num_cores * a.link_bw
        assert a.preload_hops == 1.0
        assert a.dist_hops == 1.0
        assert a.preload_noc_bw == a.noc_capacity
        assert a.noc_occupancy(3e9, 5e9, 7e9) == pytest.approx(
            (3e9 + 5e9 + 7e9) / a.noc_capacity)
        m = chip_for("mesh2d")
        r, c = m.mesh_shape
        assert m.noc_capacity == 4 * m.num_cores * m.link_bw
        assert m.preload_hops == max((r + c) / 4.0, 1.0)
        assert m.dist_hops == 2.0
        assert m.preload_noc_bw == m.noc_capacity / m.preload_hops
        assert m.noc_occupancy(3e9, 5e9, 7e9) == pytest.approx(
            (3e9 + 5e9 * m.preload_hops + 7e9 * 2.0) / m.noc_capacity)

    def test_unknown_topology_rejected_at_construction(self):
        with pytest.raises(ValueError, match="unknown topology"):
            ipu_pod4_hbm(topology="hypercube")

    def test_hier_pod_rejects_degenerate_inter_tier(self):
        with pytest.raises(ValueError, match="inter_bw_ratio"):
            chip_for("hier_pod").scaled(inter_bw_ratio=0.0)
        with pytest.raises(ValueError, match="inter_bw_ratio"):
            chip_for("hier_pod").scaled(inter_links_per_chip=0)
        # harmless on flat topologies, caught when switching to hier_pod
        flat = chip_for("all2all").scaled(inter_bw_ratio=0.0)
        with pytest.raises(ValueError):
            flat.scaled(topology="hier_pod")

    def test_hier_pod_has_distinct_slower_inter_tier(self):
        t = chip_for("hier_pod").topo
        by_name = {lc.name: lc for lc in t.classes}
        assert set(by_name) == {"intra", "inter"}
        assert by_name["inter"].capacity < by_name["intra"].capacity
        assert by_name["inter"].hop_latency > by_name["intra"].hop_latency
        # preload stays on-chip; distribution crosses the thin tier
        assert "inter" not in t.flow_weights("preload")
        assert t.flow_weights("dist")["inter"] > 0
        assert t.dist_time_factor > 1.0
        # the slower inter hop latency is consumed by distribution costs
        assert t.dist_latency == pytest.approx(
            by_name["intra"].hop_latency + by_name["inter"].hop_latency)
        assert t.dist_latency > chip_for("all2all").topo.dist_latency


# ---------------------------------------------------------------------------
# torus <= mesh monotonicity at equal link_bw
# ---------------------------------------------------------------------------

class TestTorusVsMesh:
    def test_routing_and_bisection(self):
        mesh, torus = chip_for("mesh2d").topo, chip_for("torus2d").topo
        assert torus.preload_hops <= mesh.preload_hops
        assert torus.dist_hops <= mesh.dist_hops
        assert torus.bisection_bw == pytest.approx(2 * mesh.bisection_bw)
        assert torus.preload_delivery_bw >= mesh.preload_delivery_bw

    def test_rotation_and_occupancy_monotone(self):
        mesh, torus = chip_for("mesh2d"), chip_for("torus2d")
        cm, ct = AnalyticCostModel(mesh), AnalyticCostModel(torus)
        for vol in (64 * KB, 4096 * KB):
            assert ct.rot_time(vol, rounds=3) <= cm.rot_time(vol, rounds=3)
            assert ct.dist_time(vol) <= cm.dist_time(vol)
        assert torus.noc_occupancy(1e9, 1e9, 1e9) <= \
            mesh.noc_occupancy(1e9, 1e9, 1e9)


# ---------------------------------------------------------------------------
# mesh_shape prime fallback
# ---------------------------------------------------------------------------

class TestNearSquareGrid:
    def test_composite_untouched(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert near_square_grid(1472) == (32, 46)
            assert near_square_grid(12) == (3, 4)
            assert near_square_grid(1) == (1, 1)

    @pytest.mark.parametrize("n", (23, 46, 97, 5881))
    def test_degenerate_pads_to_composite_and_warns(self, n):
        """Primes and 2*prime pencils alike: anything worse than 2:1 pads."""
        with pytest.warns(UserWarning, match="padding"):
            r, c = near_square_grid(n)
        assert r > 1 and c <= 2 * r and r * c >= n

    def test_prime_core_count_mesh_not_degenerate(self):
        chip = ipu_pod4_hbm(topology="mesh2d").scaled(num_cores=23 * 4)
        with pytest.warns(UserWarning, match="padding"):
            r, c = chip.mesh_shape
        assert (r, c) == (4, 6)
        # padded grid keeps preload_hops far below the (1, 23) pencil's
        assert chip.preload_hops < (1 + 23) / 4.0


# ---------------------------------------------------------------------------
# simulator: flow conservation + latency charging
# ---------------------------------------------------------------------------

class TestSimulator:
    @pytest.mark.parametrize("topo", ("all2all", "torus2d", "hier_pod"))
    def test_breakdown_components_sum_to_total(self, small_graph, topo):
        chip = chip_for(topo)
        plan = build_plan(small_graph, chip, "ELK-Dyn")
        sim = simulate(plan, chip)
        bd = sim.breakdown
        assert bd.total == pytest.approx(sim.total_time, rel=1e-9)
        assert sim.total_time > 0
        assert 0.0 <= sim.util.interconnect <= 1.0
        assert 0.0 <= sim.util.hbm <= 1.0

    def test_latencies_charged_to_flows(self, small_graph):
        """Bugfix: per-request hbm_latency and per-hop link_latency stretch
        the simulated schedule (the seed simulator ignored both)."""
        base = chip_for("all2all")
        zero = base.scaled(link_latency=0.0, hbm_latency=0.0)
        slow = base.scaled(link_latency=5e-6, hbm_latency=20e-6)
        plan = build_plan(small_graph, zero, "ELK-Dyn")
        t_zero = simulate(plan, zero).total_time
        t_base = simulate(plan, base).total_time
        t_slow = simulate(plan, slow).total_time
        assert t_zero < t_base < t_slow
        # at least the critical-path preload's request latency shows up
        assert t_slow - t_zero >= 20e-6

    def test_hier_pod_inter_tier_stretches_crossing_flows(self, small_graph):
        """Per-link-class contention: shrinking only the inter tier must not
        speed anything up, and a starved tier slows the pod down."""
        base = chip_for("hier_pod")
        thin = base.scaled(inter_bw_ratio=0.01)
        plan = build_plan(small_graph, base, "ELK-Dyn")
        t_base = simulate(plan, base).total_time
        t_thin = simulate(plan, thin).total_time
        assert t_thin >= t_base


# ---------------------------------------------------------------------------
# pipeline caches miss on topology change; plans react to topology
# ---------------------------------------------------------------------------

class TestTopologyCaching:
    def test_plan_cache_misses_on_topology_change(self, small_cfg):
        clear_plan_cache()
        kw = dict(batch=32, seq=2048, phase="decode", design="Basic")
        a = compile_model(small_cfg, chip_for("all2all"), **kw)
        misses_after_first = plan_cache().misses
        b = compile_model(small_cfg, chip_for("torus2d"), **kw)
        assert plan_cache().misses > misses_after_first
        assert a is not b
        # same-chip recompile still hits
        assert compile_model(small_cfg, chip_for("torus2d"), **kw) is b

    def test_topology_signature_distinguishes_parameter_changes(self):
        base = chip_for("hier_pod")
        assert base.topo_signature != \
            base.scaled(inter_bw_ratio=0.5).topo_signature
        assert base.topo_signature != \
            base.scaled(link_bw=2 * base.link_bw).topo_signature

    def test_elk_decisions_react_to_topology(self, small_cfg):
        """The compiler core — not just the simulator — is topology-aware:
        the same model under two topologies picks different preload or
        rotation (exec-plan) decisions."""
        plans = {
            topo: compile_model(small_cfg, chip_for(topo), batch=32,
                                seq=2048, phase="decode", design="ELK-Dyn",
                                cache=False)
            for topo in ("all2all", "ring")
        }
        a, r = plans["all2all"], plans["ring"]
        assert a.total_time != r.total_time

        def decision_keys(p):
            return [(d.exec_plan.key(),
                     d.preload_plan.frac if d.preload_plan else None)
                    for d in p.decisions]

        assert decision_keys(a) != decision_keys(r)

    def test_topology_latencies_distinct_and_ordered(self, small_cfg):
        """>= 2 new topologies produce distinct latencies, ordered by their
        delivery bandwidth story: all2all <= torus2d <= mesh2d <= ring."""
        lat = {}
        for topo in ("all2all", "torus2d", "mesh2d", "ring"):
            p = compile_model(small_cfg, chip_for(topo), batch=32, seq=2048,
                              phase="decode", design="ELK-Dyn", cache=False)
            lat[topo] = p.total_time
        assert lat["all2all"] <= lat["torus2d"] <= lat["mesh2d"] \
            <= lat["ring"]
        assert len({round(v, 12) for v in lat.values()}) >= 3


# ---------------------------------------------------------------------------
# collective cost API (hybrid pod planner, DESIGN.md §9)
# ---------------------------------------------------------------------------

class TestCollectiveCosts:
    BYTES = 8 << 20

    @pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
    def test_all_reduce_composes_rs_plus_ag(self, topo):
        """Ring all-reduce = reduce-scatter then all-gather, exactly; any
        drift means the two code paths stopped pricing the same links."""
        t = chip_for(topo).topo
        ar = t.collective_time("all_reduce", self.BYTES, 4)
        rs = t.collective_time("reduce_scatter", self.BYTES, 4)
        ag = t.collective_time("all_gather", self.BYTES, 4)
        assert ar >= rs + ag - 1e-15          # composition lower bound
        assert ar == pytest.approx(rs + ag)

    @pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
    @pytest.mark.parametrize("kind", ("all_reduce", "reduce_scatter",
                                      "all_gather", "all_to_all"))
    def test_monotone_in_bytes_and_width(self, topo, kind):
        t = chip_for(topo).topo
        assert t.collective_time(kind, 2 * self.BYTES, 4) > \
            t.collective_time(kind, self.BYTES, 4)
        assert t.collective_time(kind, self.BYTES, 4) > \
            t.collective_time(kind, self.BYTES, 2)
        assert t.collective_time(kind, self.BYTES, 1) == 0.0
        assert t.collective_time(kind, 0, 4) == 0.0

    def test_topology_ordering_fixed_bytes(self):
        """Lower bisection per chip pair => slower collective: ring >=
        torus2d >= all2all at fixed payload and width."""
        times = {topo: chip_for(topo).topo.collective_time(
            "all_reduce", self.BYTES, 4)
            for topo in ("all2all", "torus2d", "ring")}
        assert times["ring"] >= times["torus2d"] >= times["all2all"]

    def test_hier_pod_boundary_matches_chip_view(self):
        """The collective's chip-pair boundary prices the same gateway
        links chip_view() exposes for stage-to-stage sends."""
        chip = chip_for("hier_pod")
        view = chip.chip_view()
        one_pass = chip.topo.collective_time("all_gather", self.BYTES, 2)
        expect = (self.BYTES / 2) / view.inter_bw + view.inter_latency
        assert one_pass == pytest.approx(expect)

    def test_rejects_unknown_kind_width_and_class(self):
        chip = chip_for("all2all")
        with pytest.raises(ValueError, match="collective kind"):
            chip.topo.collective_time("broadcast", 1024, 2)
        with pytest.raises(ValueError, match="out of range"):
            chip.topo.collective_time("all_reduce", 1024, 99)
        with pytest.raises(ValueError, match="link class"):
            chip.topo.collective_time("all_reduce", 1024, 2,
                                      link_class="nope")
        with pytest.raises(ValueError, match="out of range"):
            chip.chip_view(99)
